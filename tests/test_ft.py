"""Fault-tolerance runtime + gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.compression import compress, decompress, with_error_feedback
from repro.runtime.ft import (AnomalyConfig, AnomalyDetector, StepWatchdog,
                              skip_or_apply)


def test_anomaly_detector_skips_nan_and_spikes():
    det = AnomalyDetector(AnomalyConfig(spike_factor=5.0, warmup_steps=5))
    for i in range(10):
        assert det.check(1.0, 1.0 + 0.01 * i)
    assert not det.check(float("nan"), 1.0)
    assert not det.check(1.0, 100.0)      # spike
    assert det.check(1.0, 1.1)            # back to normal
    assert not det.should_restart


def test_anomaly_restart_signal():
    det = AnomalyDetector(AnomalyConfig(max_skips_in_row=3, warmup_steps=0,
                                        spike_factor=2.0))
    det.check(1.0, 1.0)
    for _ in range(3):
        det.check(1.0, 1e9)
    assert det.should_restart


def test_skip_or_apply():
    old = {"w": jnp.zeros((3,))}
    new = {"w": jnp.ones((3,))}
    np.testing.assert_array_equal(
        np.asarray(skip_or_apply(jnp.bool_(True), new, old)["w"]), 1.0)
    np.testing.assert_array_equal(
        np.asarray(skip_or_apply(jnp.bool_(False), new, old)["w"]), 0.0)


def test_watchdog_flags_sustained_slowdown():
    import time
    dog = StepWatchdog(slow_factor=3.0, patience=2)
    for _ in range(3):
        dog.start(); time.sleep(0.002); dog.stop()
    assert not dog.straggling
    for _ in range(2):
        dog.start(); time.sleep(0.03); dog.stop()
    assert dog.straggling


def test_compression_roundtrip_error():
    g = {"a": jax.random.normal(jax.random.PRNGKey(0), (512,)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 10}
    qs, scales = compress(g)
    back = decompress(qs, scales)
    for k in g:
        err = np.abs(np.asarray(back[k] - g[k])).max()
        assert err <= np.abs(np.asarray(g[k])).max() / 127 + 1e-7


def test_error_feedback_reduces_bias():
    """With error feedback, the accumulated applied updates track the true
    gradient sum much closer than independent quantization."""
    key = jax.random.PRNGKey(0)
    true_sum = jnp.zeros((256,))
    applied_ef = jnp.zeros((256,))
    residual = None
    for i in range(50):
        g = {"g": jax.random.normal(jax.random.fold_in(key, i), (256,))
             * 0.001 + 0.01}
        true_sum = true_sum + g["g"]
        deq, residual = with_error_feedback(g, residual)
        applied_ef = applied_ef + deq["g"]
    err = float(jnp.abs(applied_ef - true_sum).max())
    # residual carries at most one step's quantization error
    one_step_err = 0.02 / 127
    assert err < 5 * one_step_err
