"""Pallas kernels vs pure-jnp oracle: shape/dtype/mode sweeps, bit-exact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import avss as avss_lib
from repro.core.avss import SearchConfig
from repro.core.encodings import avss_sum_lut, make_encoding
from repro.core.mcam import MCAMConfig
from repro.kernels import ops, ref
from repro.kernels.mcam_search import mcam_search_pallas


def _layouts(mode, enc, d, N, B, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    sv = jax.random.randint(k1, (N, d), 0, enc.levels)
    qmax = 4 if mode == "avss" else enc.levels
    qv = jax.random.randint(k2, (B, d), 0, qmax)
    return qv, sv


@pytest.mark.parametrize("mode", ["avss", "svss"])
@pytest.mark.parametrize("encoding,cl", [("mtmc", 4), ("mtmc", 9),
                                         ("b4e", 2), ("sre", 3)])
@pytest.mark.parametrize("d", [10, 48])
@pytest.mark.slow
def test_search_kernel_matches_ref(mode, encoding, cl, d):
    cfg = SearchConfig(encoding=encoding, cl=cl, mode=mode,
                       mcam=MCAMConfig(sigma_device=0.1, sigma_read=0.05))
    enc = cfg.enc
    qv, sv = _layouts(mode, enc, d, N=40, B=5)
    sl = cfg.mcam.string_len
    s_grid = avss_lib.layout_support(sv, enc, sl)
    q_grid = avss_lib.layout_query(qv, enc, mode, sl)
    th = jnp.asarray(cfg.mcam.thresholds())
    # kernel (padded tiles) vs oracle
    votes_k, dist_k = ops.mcam_search(q_grid, s_grid, enc.weights_array(),
                                      cfg, th)
    L = s_grid.shape[2]
    q = ops.flatten_strings(ops.broadcast_query(q_grid, L)).astype(jnp.int8)
    s = ops.flatten_strings(s_grid).astype(jnp.int8)
    w = jnp.tile(enc.weights_array(), s_grid.shape[1])
    votes_r, dist_r = ref.mcam_search_ref(q, s, w, th, cfg.mcam, noisy=True)
    np.testing.assert_allclose(np.asarray(votes_k), np.asarray(votes_r),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(dist_k), np.asarray(dist_r),
                               rtol=1e-6)


@pytest.mark.parametrize("tile_b,tile_n", [(2, 16), (8, 64)])
@pytest.mark.slow
def test_kernel_tiling_invariance(tile_b, tile_n):
    """Different VMEM tilings must produce bit-identical results."""
    cfg = SearchConfig(encoding="mtmc", cl=6, mode="avss")
    enc = cfg.enc
    qv, sv = _layouts("avss", enc, 24, N=64, B=8)
    s_grid = avss_lib.layout_support(sv, enc, 24)
    q_grid = avss_lib.layout_query(qv, enc, "avss", 24)
    th = jnp.asarray(cfg.mcam.thresholds())
    L = s_grid.shape[2]
    q = ops.flatten_strings(ops.broadcast_query(q_grid, L)).astype(jnp.int8)
    s = ops.flatten_strings(s_grid).astype(jnp.int8)
    w = jnp.tile(enc.weights_array(), s_grid.shape[1])
    v1, d1 = mcam_search_pallas(q, s, w, th, cfg.mcam, tile_b=tile_b,
                                tile_n=tile_n)
    v2, d2 = ref.mcam_search_ref(q, s, w, th, cfg.mcam)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_noiseless_dist_equals_weighted_l1():
    cfg = SearchConfig(encoding="mtmc", cl=8, mode="svss", noisy=False,
                       use_kernel="ref")
    enc = cfg.enc
    qv, sv = _layouts("svss", enc, 16, N=30, B=4)
    res = avss_lib.search_quantized(qv, sv, cfg)
    expect = np.abs(np.asarray(qv)[:, None] - np.asarray(sv)[None]).sum(-1)
    np.testing.assert_allclose(np.asarray(res["dist"]), expect)


@pytest.mark.parametrize("cl", [2, 8, 32])
@pytest.mark.parametrize("d", [16, 48, 100])
def test_mxu_lut_dist_exact(cl, d):
    enc = make_encoding("mtmc", cl)
    qv, sv = _layouts("avss", enc, d, N=70, B=6, seed=cl + d)
    di = ops.avss_ideal_dist(qv, sv, enc)
    dr = ref.avss_dist_ref(qv, sv, jnp.asarray(avss_sum_lut(enc)))
    np.testing.assert_array_equal(np.asarray(di), np.asarray(dr))
    # against direct value-space distance |cl*q - v|
    expect = np.abs(cl * np.asarray(qv)[:, None] - np.asarray(sv)[None]
                    ).sum(-1).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(di), expect)


@pytest.mark.slow
def test_two_phase_matches_full_search():
    cfg = SearchConfig(encoding="mtmc", cl=8, mode="avss", use_kernel="ref")
    enc = cfg.enc
    qv, sv = _layouts("avss", enc, 48, N=64, B=8)
    full = avss_lib.search_quantized(qv, sv, cfg)
    tp = ops.two_phase_search(qv, sv, cfg, k=64)  # k=N: full coverage
    # same noise counters => identical votes for every support
    order = np.argsort(np.asarray(tp["indices"]), axis=1)
    votes_sorted = np.take_along_axis(np.asarray(tp["votes"]), order, 1)
    np.testing.assert_allclose(votes_sorted, np.asarray(full["votes"]),
                               rtol=1e-5)


@pytest.mark.slow
def test_two_phase_winner_agreement():
    """Shortlist recall: on UNSTRUCTURED random vectors (worst case: many
    near-ties) k=64/200 already recovers the exact noisy-vote winner; the
    recall-vs-k curve is benchmarked in benchmarks/bench_kernels.py."""
    cfg = SearchConfig(encoding="mtmc", cl=8, mode="avss", use_kernel="ref")
    enc = cfg.enc
    qv, sv = _layouts("avss", enc, 48, N=200, B=8)
    full = avss_lib.search_quantized(qv, sv, cfg)
    agree = {}
    for k in (32, 64):
        tp = ops.two_phase_search(qv, sv, cfg, k=k)
        full_best = np.asarray(jnp.argmax(
            full["votes"] - 1e-6 * full["dist"], -1))
        sc = np.asarray(tp["votes"]) - 1e-6 * np.asarray(tp["dist"])
        tp_best = np.asarray(tp["indices"])[np.arange(8), sc.argmax(1)]
        agree[k] = (full_best == tp_best).mean()
    assert agree[64] >= 0.95, agree
    assert agree[32] >= 0.5, agree
    assert agree[64] >= agree[32]
