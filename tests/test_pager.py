"""Host-paging suite (repro/engine/pager.py, PR 10).

The tentpole's contract, part (b): a partitioned store with
`residency="host"` keeps its row blocks in host memory; `ShardPager`
pages the router's top-nprobe shards into a small LRU working set of
device slot tables and runs the SAME jitted routed-block search the
device-resident path uses -- so every paged search is bit-identical to
`RetrievalEngine.search(device_twin, q, request)` with the same nprobe.
Steady-state paging must be clean under `jax.transfer_guard("disallow")`
(all host<->device movement is explicit `device_put` / `device_get`),
and a paged store round-trips through save/restore bit-identically.
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.avss import SearchConfig
from repro.engine import MemoryStore, RetrievalEngine, SearchRequest
from repro.engine.pager import ShardPager

N, DIM, S = 144, 12, 8


def _cfg(backend="mxu"):
    return SearchConfig("mtmc", cl=8, mode="avss", use_kernel=backend)


@pytest.fixture(scope="module")
def paged_fixture():
    """(host_store, device_twin, engine, queries): one partitioned store
    in both residencies, built from the same rows (with masked labels)."""
    cfg = _cfg()
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.integers(0, 16, (N, DIM)))
    labs = np.arange(N) % 9
    labs[labs % 4 == 3] = -1
    store = MemoryStore.from_quantized(vals, jnp.asarray(labs), cfg)
    q = jnp.asarray(rng.integers(0, 4, (5, DIM)))
    return (store.shard(n_shards=S, residency="host"),
            store.shard(n_shards=S), RetrievalEngine(cfg), q)


def _assert_equal(a, b, ctx=""):
    for f in ("votes", "dist", "indices", "labels"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{ctx}: {f}")


@pytest.mark.parametrize("mode", ["two_phase", "ideal"])
@pytest.mark.parametrize("nprobe", [1, 2, 3])
def test_paged_search_bit_identical_to_device_twin(paged_fixture, mode,
                                                   nprobe):
    host, dev, eng, q = paged_fixture
    pager = ShardPager(host, eng, slots=S)
    req = SearchRequest(mode=mode, k=10, nprobe=nprobe)
    _assert_equal(pager.search(q, req), eng.search(dev, q, req),
                  f"{mode}/nprobe={nprobe}")


def test_steady_state_is_transfer_guard_clean(paged_fixture):
    """After the warm-up call (compilation embeds LUT constants), every
    paged search -- including ones that page NEW shards in -- runs under
    jax.transfer_guard('disallow')."""
    host, dev, eng, q = paged_fixture
    pager = ShardPager(host, eng, slots=4)
    req = SearchRequest(mode="two_phase", k=8, nprobe=2)
    q1, req3 = q[:1], SearchRequest(mode="two_phase", k=8, nprobe=3)
    pager.search(q, req)                       # warm-up: compile both
    pager.search(q1, req3)                     # (batch, request) combos
    # evict everything the warm-ups left resident, so the guarded
    # searches below must page their shards back in
    pager.ensure([s for s in range(S) if s not in pager.resident()][:4])
    before = pager.pages_in
    with jax.transfer_guard("disallow"):
        res = pager.search(q, req)
        res2 = pager.search(q1, req3)
    assert pager.pages_in > before             # paging DID happen guarded
    _assert_equal(res, eng.search(dev, q, req), "guarded")
    res2.votes.block_until_ready()


def test_lru_eviction_and_warm_hits(paged_fixture):
    """2 slots, single-query working sets: shards page in and out through
    eviction with per-search parity, repeats are warm hits (no paging),
    and residency never exceeds the slot count."""
    host, dev, eng, _ = paged_fixture
    rng = np.random.default_rng(1)
    pager = ShardPager(host, eng, slots=2, prefetch=False)
    req = SearchRequest(mode="two_phase", k=6, nprobe=1)
    queries = [jnp.asarray(rng.integers(0, 4, (1, DIM))) for _ in range(8)]
    seen = set()
    for q1 in queries:
        _assert_equal(pager.search(q1, req), eng.search(dev, q1, req))
        assert len(pager.resident()) <= 2
        seen.update(pager.resident())
    assert len(seen) > 2, "fixture never exercised eviction"
    before = pager.pages_in
    pager.search(queries[-1], req)             # warm hit
    assert pager.pages_in == before


def test_prefetch_stages_a_spare_shard(paged_fixture):
    """With head-room, the (nprobe+1)-th-best shard is staged after the
    search, and consuming it later costs no host->device block copy at
    ensure() time beyond the install."""
    host, _, eng, q = paged_fixture
    pager = ShardPager(host, eng, slots=4, prefetch=True)
    pager.search(q[:1], SearchRequest(mode="ideal", k=6, nprobe=2))
    assert len(pager._staged) == 1             # double-buffer in flight
    staged = next(iter(pager._staged))
    assert staged not in pager.resident()
    pager.ensure([staged])                     # consume the staged copy
    assert staged in pager.resident() and not pager._staged


def test_batch_union_exceeding_slots_raises(paged_fixture):
    host, _, eng, q = paged_fixture
    pager = ShardPager(host, eng, slots=2)
    with pytest.raises(ValueError, match="device slots"):
        pager.search(q, SearchRequest(mode="ideal", k=6, nprobe=2))


def test_constructor_validation(paged_fixture):
    host, dev, eng, _ = paged_fixture
    with pytest.raises(ValueError, match="slots"):
        ShardPager(host, eng, slots=S + 1)
    unpartitioned = host._unpad()
    with pytest.raises(ValueError, match="partitioned"):
        ShardPager(unpartitioned, eng)


def test_nprobe_required_and_bounded(paged_fixture):
    host, _, eng, q = paged_fixture
    pager = ShardPager(host, eng, slots=4)
    with pytest.raises(ValueError, match="nprobe"):
        pager.search(q, SearchRequest(mode="ideal", k=4))     # no nprobe
    with pytest.raises(ValueError, match="nprobe"):
        pager.search(q, SearchRequest(mode="ideal", k=4, nprobe=S + 1))


def test_paged_store_save_restore_bit_identical(paged_fixture):
    """save() -> restore() -> re-shard(residency='host') reproduces every
    leaf (sketch included -- rebuilt deterministically) and every paged
    search result bit-for-bit."""
    host, _, eng, q = paged_fixture
    with tempfile.TemporaryDirectory() as td:
        host.save(td, 0)
        back = MemoryStore.restore(td, host.cfg).shard(
            n_shards=S, residency="host")
    for f in ("values", "proj", "proj_packed", "s_grid", "labels",
              "sketch_sums", "sketch_counts", "lo", "hi", "size"):
        np.testing.assert_array_equal(np.asarray(getattr(host, f)),
                                      np.asarray(getattr(back, f)),
                                      err_msg=f)
    assert back.residency == "host" and back.n_shards == S
    req = SearchRequest(mode="two_phase", k=10, nprobe=2)
    _assert_equal(ShardPager(host, eng, slots=S).search(q, req),
                  ShardPager(back, eng, slots=S).search(q, req))
