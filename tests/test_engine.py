"""Parity suite for the unified retrieval engine (repro/engine).

Every backend and every sharding must produce BIT-IDENTICAL results -- the
engine's contract is that backend choice is purely a performance decision.
Exactness rests on (see repro/engine docstrings): integer-valued phase-1
distances (exact in f32 under any reduction order), (distance, index)
lexicographic ranking everywhere, and counter-based noise keyed on absolute
(query, string, cell) coordinates.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo_contracts as hc
from repro.core import avss as avss_lib
from repro.core.avss import SearchConfig
from repro.core.mcam import MCAMConfig
from repro.engine import RetrievalEngine, resolve_backend

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Backend resolution.
# ---------------------------------------------------------------------------


def test_resolve_backend_precedence():
    assert resolve_backend("ref", "pallas") == "ref"   # engine overrides cfg
    assert resolve_backend("auto", "ref") == "ref"     # cfg honoured on auto
    assert resolve_backend("auto", "auto") in ("pallas", "ref")
    with pytest.raises(ValueError):
        resolve_backend("cuda")


# ---------------------------------------------------------------------------
# (a) Pallas full search == reference, across odd shapes.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,N,d", [
    (5, 200, 50),   # B not a tile_b multiple, N crosses a tile_n boundary,
                    # d not a string_len multiple (50 = 2*24 + 2)
    (3, 37, 10),    # tiny everything
    pytest.param(9, 130, 24, marks=pytest.mark.slow),  # d exactly 1 string
    pytest.param(1, 16, 72, marks=pytest.mark.slow),   # 1 query, 3 strings
])
def test_full_search_pallas_matches_ref_odd_shapes(B, N, d):
    cfg = SearchConfig("mtmc", cl=8, mode="avss", mcam=MCAMConfig(),
                       use_kernel="ref")
    sv = jax.random.randint(jax.random.PRNGKey(N), (N, d), 0, cfg.enc.levels)
    qv = jax.random.randint(jax.random.PRNGKey(B), (B, d), 0, 4)
    ref = RetrievalEngine(cfg, backend="ref").full(qv, sv)
    pal = RetrievalEngine(cfg, backend="pallas").full(qv, sv)
    np.testing.assert_array_equal(np.asarray(ref["votes"]),
                                  np.asarray(pal["votes"]))
    np.testing.assert_array_equal(np.asarray(ref["dist"]),
                                  np.asarray(pal["dist"]))


@pytest.mark.slow
def test_full_search_pallas_matches_ref_svss():
    cfg = SearchConfig("mtmc", cl=4, mode="svss", mcam=MCAMConfig(),
                       use_kernel="ref")
    sv = jax.random.randint(jax.random.PRNGKey(2), (33, 30), 0,
                            cfg.enc.levels)
    qv = jax.random.randint(jax.random.PRNGKey(3), (4, 30), 0,
                            cfg.enc.levels)
    ref = RetrievalEngine(cfg, backend="ref").full(qv, sv)
    pal = RetrievalEngine(cfg, backend="pallas").full(qv, sv)
    np.testing.assert_array_equal(np.asarray(ref["votes"]),
                                  np.asarray(pal["votes"]))


# ---------------------------------------------------------------------------
# Two-phase backends agree bit-exactly; votes match the full search.
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_two_phase_backends_bit_identical(quantized_store):
    cfg, qv, sv = quantized_store
    res = {b: RetrievalEngine(cfg, backend=b).two_phase(qv, sv, k=48)
           for b in ("ref", "mxu", "fused")}
    for b in ("mxu", "fused"):
        for key in ("votes", "dist", "indices"):
            np.testing.assert_array_equal(
                np.asarray(res["ref"][key]), np.asarray(res[b][key]),
                err_msg=f"{b}/{key}")


@pytest.mark.slow
def test_two_phase_votes_match_full_search(quantized_store):
    cfg, qv, sv = quantized_store
    eng = RetrievalEngine(cfg, backend="ref")
    full = eng.full(qv, sv)
    tp = eng.two_phase(qv, sv, k=48)
    v_full = np.asarray(full["votes"])
    idx = np.asarray(tp["indices"])
    for b in range(qv.shape[0]):
        np.testing.assert_array_equal(np.asarray(tp["votes"])[b],
                                      v_full[b, idx[b]])


def test_fused_shortlist_matches_topk_tie_heavy():
    """The fused Pallas shortlist reproduces lax.top_k EXACTLY, including
    tie order, on a store built almost entirely of duplicated rows."""
    from repro.core.encodings import make_encoding
    from repro.kernels import ops as kops
    enc = make_encoding("mtmc", 8)
    base = jax.random.randint(jax.random.PRNGKey(0), (8, 20), 0, enc.levels)
    sv = jnp.concatenate([base] * 9, axis=0)               # 72 rows, 9x dups
    qv = jax.random.randint(jax.random.PRNGKey(1), (5, 20), 0, 4)
    q1h = kops.query_onehot(qv, jnp.float32)
    sp = kops.support_projection(sv, enc, jnp.float32)
    neg, idx_ref = jax.lax.top_k(-(q1h @ sp.T), 30)
    dist, idx = kops.lut_shortlist(qv, sv, enc, 30)
    np.testing.assert_array_equal(np.asarray(-neg), np.asarray(dist))
    np.testing.assert_array_equal(np.asarray(idx_ref), np.asarray(idx))


def test_shortlist_valid_mask_excludes_rows():
    """Masked rows rank after every valid row (integer-exact penalty), and
    masking is bit-identical across shortlist backends."""
    cfg = SearchConfig("mtmc", cl=4, mode="avss", mcam=MCAMConfig(),
                       use_kernel="ref")
    sv = jax.random.randint(jax.random.PRNGKey(0), (40, 16), 0,
                            cfg.enc.levels)
    qv = jax.random.randint(jax.random.PRNGKey(1), (3, 16), 0, 4)
    valid = (jnp.arange(40) % 3) != 0                      # 26 valid rows
    res = {b: RetrievalEngine(cfg, backend=b).two_phase(qv, sv, k=20,
                                                        valid=valid)
           for b in ("ref", "mxu", "fused")}
    for b in ("mxu", "fused"):
        for key in ("votes", "dist", "indices"):
            np.testing.assert_array_equal(
                np.asarray(res["ref"][key]), np.asarray(res[b][key]),
                err_msg=f"{b}/{key}")
    # k=20 <= 26 valid rows: no masked row may appear at all
    assert bool(jnp.all(valid[res["ref"]["indices"]]))


# ---------------------------------------------------------------------------
# Ideal mode: fused shortlist kernel == dense (B, N) matmul, bit for bit.
# ---------------------------------------------------------------------------


def test_ideal_fused_matches_dense_tie_heavy():
    """The large-N ideal serving path (fused shortlist kernel) is
    bit-identical to the dense-matmul reference -- votes, dist, indices
    AND labels -- on a tie-heavy store with masked rows inside the top-k
    (masked rows carry the integer-exact penalty in both paths)."""
    from repro.engine import MemoryStore, SearchRequest
    cfg = SearchConfig("mtmc", cl=8, mode="avss", use_kernel="ref")
    base = jax.random.randint(jax.random.PRNGKey(0), (8, 20), 0,
                              cfg.enc.levels)
    sv = jnp.concatenate([base] * 9, axis=0)            # 72 rows, 9x dups
    labels = jnp.where(jnp.arange(72) % 4 == 0, -1,
                       jnp.arange(72)).astype(jnp.int32)  # 18 masked rows
    store = MemoryStore.from_quantized(sv, labels, cfg)
    qv = jax.random.randint(jax.random.PRNGKey(1), (5, 20), 0, 4)
    req = SearchRequest(mode="ideal", k=70)             # reaches masked rows
    dense = RetrievalEngine(cfg, backend="ref").search(store, qv, req)
    fused = RetrievalEngine(cfg, backend="fused").search(store, qv, req)
    for key in ("votes", "dist", "indices", "labels"):
        np.testing.assert_array_equal(np.asarray(getattr(dense, key)),
                                      np.asarray(getattr(fused, key)),
                                      err_msg=key)
    # masked candidates surface as -inf votes / -1 labels in both
    assert np.isneginf(np.asarray(dense.votes)).any()


def test_ideal_routes_through_fused_kernel_at_large_n(monkeypatch):
    """Acceptance (ISSUE 3): at N >= IDEAL_FUSED_MIN_ROWS the unsharded
    ideal mode streams through kernels/shortlist.py instead of
    materialising the dense (B, N) matrix; small stores and the ref
    backend keep the dense reference."""
    from repro.engine import MemoryStore, SearchRequest
    from repro.engine.engine import IDEAL_FUSED_MIN_ROWS
    from repro.kernels import ops as kernel_ops
    cfg = SearchConfig("mtmc", cl=8, mode="avss", use_kernel="auto")
    N = IDEAL_FUSED_MIN_ROWS
    sv = jnp.tile(jax.random.randint(jax.random.PRNGKey(0), (128, 16), 0,
                                     cfg.enc.levels), (N // 128, 1))
    store = MemoryStore.from_quantized(
        sv, jnp.arange(N, dtype=jnp.int32) % 17, cfg)
    small = MemoryStore.from_quantized(
        sv[:64], jnp.arange(64, dtype=jnp.int32), cfg)
    qv = jax.random.randint(jax.random.PRNGKey(1), (3, 16), 0, 4)
    req = SearchRequest(mode="ideal", k=16)

    calls = []
    orig = kernel_ops.lut_shortlist
    monkeypatch.setattr(kernel_ops, "lut_shortlist",
                        lambda *a, **kw: (calls.append(1), orig(*a, **kw))[1])
    eng = RetrievalEngine(cfg)                  # auto -> pallas (kernels)
    assert eng.resolved_backend != "ref"
    fused_res = eng.search(store, qv, req)
    assert len(calls) == 1, "large-N ideal must use the fused shortlist"
    eng.search(small, qv, req)
    assert len(calls) == 1, "small-N ideal keeps the dense matmul"
    ref_res = RetrievalEngine(cfg, backend="ref").search(store, qv, req)
    assert len(calls) == 1, "ref backend keeps the dense reference"
    for key in ("votes", "dist", "indices", "labels"):
        np.testing.assert_array_equal(np.asarray(getattr(ref_res, key)),
                                      np.asarray(getattr(fused_res, key)),
                                      err_msg=key)


# ---------------------------------------------------------------------------
# Fused shortlist on the SHARDED paths (ISSUE 4 tentpole): the kernel is the
# one shortlist implementation across unsharded/sharded x ref/mxu/fused.
# ---------------------------------------------------------------------------


def test_shortlist_kernel_native_mask_odd_n_ties():
    """kernels/shortlist.py handles masked rows natively (per-row penalty
    block stream) and non-tile-aligned row counts: bit-identical to the
    dense penalised matrix + lax.top_k, masked rows in the top-k included,
    on a tie-heavy 45-row (odd) store with a bf16 projection."""
    from repro.core.encodings import make_encoding
    from repro.kernels import ops as kops
    from repro.kernels.shortlist import (SHORTLIST_MASK_PENALTY,
                                         lut_shortlist_pallas)
    enc = make_encoding("mtmc", 8)
    base = jax.random.randint(jax.random.PRNGKey(0), (9, 20), 0, enc.levels)
    sv = jnp.concatenate([base] * 5, axis=0)[:45]          # 45 rows, ties
    qv = jax.random.randint(jax.random.PRNGKey(1), (5, 20), 0, 4)
    valid = (jnp.arange(45) % 3) != 0                      # 15 masked rows
    q1h = kops.query_onehot(qv, jnp.float32)
    sp32 = kops.support_projection(sv, enc, jnp.float32)
    dense = q1h @ sp32.T + jnp.where(valid, 0.0,
                                     SHORTLIST_MASK_PENALTY)[None]
    neg, idx_ref = jax.lax.top_k(-dense, 40)               # masked in top-k
    sp16 = kops.support_projection(sv, enc)                # bf16 write-time
    dist, idx = lut_shortlist_pallas(q1h, sp16, 40, valid=valid)
    np.testing.assert_array_equal(np.asarray(-neg), np.asarray(dist))
    np.testing.assert_array_equal(np.asarray(idx_ref), np.asarray(idx))
    # the penalty is integer-exact and visible on masked candidates
    assert float(dist[0, -1]) >= SHORTLIST_MASK_PENALTY


def test_shortlist_kernel_packed_operand_k_over_lane():
    """The bit-packed projection operand (MemoryStore.proj_packed layout)
    feeds the kernel bit-identically to the unpacked matrix, including
    k > 128 (above the lane width) and k not a lane multiple, with masked
    rows landing inside the top-k of a tie-heavy store."""
    from repro.core.encodings import make_encoding
    from repro.kernels import ops as kops
    from repro.kernels.shortlist import (SHORTLIST_MASK_PENALTY,
                                         lut_shortlist_pallas)
    enc = make_encoding("mtmc", 8)
    base = jax.random.randint(jax.random.PRNGKey(2), (10, 16), 0, enc.levels)
    sv = jnp.concatenate([base] * 15, axis=0)              # 150 rows, ties
    qv = jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0, 4)
    valid = (jnp.arange(150) % 4) != 0                     # masked in top-k
    q1h = kops.query_onehot(qv, jnp.float32)
    proj = kops.support_projection(sv, enc, jnp.float32)
    dense = q1h @ proj.T + jnp.where(valid, 0.0,
                                     SHORTLIST_MASK_PENALTY)[None]
    k = 131                                                # > 128, not 128*m
    neg, idx_ref = jax.lax.top_k(-dense, k)
    packed = kops.pack_projection(proj, enc)
    bits = kops.projection_pack_bits(enc, proj.dtype)
    dist, idx = lut_shortlist_pallas(q1h, None, k, valid=valid,
                                     packed=packed, pack_bits=bits)
    np.testing.assert_array_equal(np.asarray(-neg), np.asarray(dist))
    np.testing.assert_array_equal(np.asarray(idx_ref), np.asarray(idx))


def test_packed_shortlist_pack_bits_is_pack_time_width():
    """Regression: the unpack width must be the PACK-time width, never
    re-derived from a default dtype. b4e cl=8 is the edge that catches it:
    the max LUT entry (65535) rounds to 65536 in bf16, so
    projection_pack_bits says 32 for a bf16 projection but 16 for the f32
    projection the store actually packs. Deriving bits from the bf16
    default while holding a 16-bit-packed operand mis-unpacks every field;
    `pack_bits` (MemoryStore.pack_bits) pins the width end to end."""
    from repro.core.encodings import make_encoding
    from repro.kernels import ops as kops
    from repro.kernels.shortlist import lut_shortlist_pallas
    enc = make_encoding("b4e", 8)
    # the widths genuinely diverge on this encoding -- the test's premise
    assert kops.projection_pack_bits(enc, jnp.float32) == 16
    assert kops.projection_pack_bits(enc, jnp.bfloat16) == 32
    base = jax.random.randint(jax.random.PRNGKey(6), (9, 12), 0, enc.levels)
    sv = jnp.concatenate([base] * 4, axis=0)               # 36 rows, ties
    qv = jax.random.randint(jax.random.PRNGKey(7), (3, 12), 0, 4)
    q1h = kops.query_onehot(qv, jnp.float32)
    proj = kops.support_projection(sv, enc, jnp.float32)   # write-time f32
    packed = kops.pack_projection(proj, enc)               # 16-bit fields
    neg, idx_ref = jax.lax.top_k(-(q1h @ proj.T), 20)
    # kernel entry point: explicit pack-time width, packed-only operand
    dist, idx = lut_shortlist_pallas(q1h, None, 20, packed=packed,
                                     pack_bits=16)
    np.testing.assert_array_equal(np.asarray(-neg), np.asarray(dist))
    np.testing.assert_array_equal(np.asarray(idx_ref), np.asarray(idx))
    # ops entry point: packed WITHOUT proj used to fall back to the bf16
    # default (32 bits); the explicit pack_bits must win
    dist2, idx2 = kops.lut_shortlist(qv, sv, enc, 20, packed=packed,
                                     pack_bits=16)
    np.testing.assert_array_equal(np.asarray(-neg), np.asarray(dist2))
    np.testing.assert_array_equal(np.asarray(idx_ref), np.asarray(idx2))


def test_shortlist_kernel_network_path_parity():
    """The compiled-TPU lowering (use_network=True: per-tile bitonic sort +
    sorted-run merge instead of lax.top_k/sort) is bit-identical to the
    dense reference, including k > tile capacity forcing k_pad widening and
    a non-tile-aligned N (jitted: the network is hundreds of eager ops)."""
    from repro.core.encodings import make_encoding
    from repro.kernels import ops as kops
    from repro.kernels.shortlist import lut_shortlist_pallas
    enc = make_encoding("mtmc", 8)
    base = jax.random.randint(jax.random.PRNGKey(4), (9, 8), 0, enc.levels)
    sv = jnp.concatenate([base] * 5, axis=0)[:44]          # 44 rows, ties
    qv = jax.random.randint(jax.random.PRNGKey(5), (3, 8), 0, 4)
    q1h = kops.query_onehot(qv, jnp.float32)
    proj = kops.support_projection(sv, enc, jnp.float32)
    neg, idx_ref = jax.lax.top_k(-(q1h @ proj.T), 40)
    f = jax.jit(lambda q, p: lut_shortlist_pallas(
        q, p, 40, tile_b=4, tile_n=16, k_pad=64, use_network=True))
    dist, idx = f(q1h, proj)
    np.testing.assert_array_equal(np.asarray(-neg), np.asarray(dist))
    np.testing.assert_array_equal(np.asarray(idx_ref), np.asarray(idx))


def test_sharded_fused_shortlist_matches_dense_and_unsharded():
    """Sharded `ideal` and `two_phase` above the fused threshold run the
    fused Pallas kernel inside shard_map (asserted on compiled HLO via the
    shortlist_fused scope tag) and stay bit-identical to the sharded-dense
    path AND the unsharded ref store -- ties and masked rows in the top-k
    included."""
    from repro.engine import MemoryStore, SearchRequest
    cfg = SearchConfig("mtmc", cl=8, mode="avss", use_kernel="ref")
    base = jax.random.randint(jax.random.PRNGKey(0), (8, 20), 0,
                              cfg.enc.levels)
    sv = jnp.concatenate([base] * 9, axis=0)               # 72 rows, ties
    labels = jnp.where(jnp.arange(72) % 4 == 0, -1,
                       jnp.arange(72)).astype(jnp.int32)   # 18 masked rows
    store = MemoryStore.from_quantized(sv, labels, cfg)
    qv = jax.random.randint(jax.random.PRNGKey(1), (5, 20), 0, 4)
    mesh = jax.make_mesh((1,), ("data",))
    sstore = store.shard(mesh, ("data",))
    eng = RetrievalEngine(cfg, backend="mxu")
    for mode in ("ideal", "two_phase"):
        ref = RetrievalEngine(cfg, backend="ref").search(
            store, qv, SearchRequest(mode=mode, k=60))
        for fmr, fused in ((1, True), (1 << 30, False)):
            req = SearchRequest(mode=mode, k=60, fused_min_rows=fmr)
            with mesh:
                got = jax.jit(lambda st, q, r=req: eng.search(st, q, r))(
                    sstore, qv)
                hlo = jax.jit(
                    lambda st, q, r=req: eng.search(st, q, r).votes
                ).lower(sstore, qv).compile().as_text()
            for key in ("votes", "dist", "indices", "labels"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(ref, key)),
                    np.asarray(getattr(got, key)),
                    err_msg=f"{mode}/fmr={fmr}/{key}")
            hc.assert_fused_tag(hlo, fused)
        # masked candidates did reach the merged top-k (k=60 > 54 valid)
        assert np.isneginf(np.asarray(ref.votes)).any(), mode


def test_fused_min_rows_knob_engine_and_request(monkeypatch):
    """IDEAL_FUSED_MIN_ROWS is a default, not a constant: the engine field
    and the per-request SearchRequest.fused_min_rows override both steer
    the dispatch (request wins), so a TPU-measured crossover applies with
    no code change."""
    from repro.engine import MemoryStore, SearchRequest
    from repro.engine.engine import IDEAL_FUSED_MIN_ROWS
    from repro.kernels import ops as kernel_ops
    cfg = SearchConfig("mtmc", cl=8, mode="avss", use_kernel="auto")
    sv = jax.random.randint(jax.random.PRNGKey(0), (64, 16), 0,
                            cfg.enc.levels)
    store = MemoryStore.from_quantized(
        sv, jnp.arange(64, dtype=jnp.int32), cfg)
    qv = jax.random.randint(jax.random.PRNGKey(1), (3, 16), 0, 4)
    calls = []
    orig = kernel_ops.lut_shortlist
    monkeypatch.setattr(kernel_ops, "lut_shortlist",
                        lambda *a, **kw: (calls.append(1), orig(*a, **kw))[1])
    default_eng = RetrievalEngine(cfg)
    assert default_eng.fused_min_rows == IDEAL_FUSED_MIN_ROWS
    req = SearchRequest(mode="ideal", k=8)
    default_eng.search(store, qv, req)           # 64 < 4096: dense
    assert not calls
    low_eng = RetrievalEngine(cfg, fused_min_rows=8)
    low_eng.search(store, qv, req)               # 64 >= 8: fused
    assert len(calls) == 1
    # request override wins over the engine field, in both directions
    low_eng.search(store, qv,
                   SearchRequest(mode="ideal", k=8, fused_min_rows=1 << 30))
    assert len(calls) == 1
    default_eng.search(store, qv,
                       SearchRequest(mode="ideal", k=8, fused_min_rows=16))
    assert len(calls) == 2
    # the two-phase shortlist obeys the same threshold (one implementation)
    default_eng.search(store, qv,
                       SearchRequest(mode="two_phase", k=8,
                                     fused_min_rows=16))
    assert len(calls) == 3


@pytest.mark.slow
def test_sharded_fused_8dev_ragged_bit_identical():
    """Acceptance (ISSUE 4 tentpole): on a forced 8-device mesh with a
    RAGGED capacity-100 split (13-row local blocks, 4 pad rows), sharded
    `ideal` and `two_phase` above the fused threshold run the fused Pallas
    shortlist kernel inside shard_map (compiled-HLO scope-tag assertion)
    with results bit-identical to the sharded-dense path and the unsharded
    store -- tie-heavy rows and masked rows (70 empty slots + 4 pads)
    inside the merged top-k."""
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.analysis import hlo_contracts as hc
        from repro.core.avss import SearchConfig
        from repro.core.memory import MemoryConfig
        from repro.engine import MemoryStore, RetrievalEngine, SearchRequest

        cfg = MemoryConfig(capacity=100, dim=24,
                           search=SearchConfig("mtmc", cl=8, mode="avss",
                                               use_kernel="ref"))
        base = jax.random.normal(jax.random.PRNGKey(0), (10, 24))
        vecs = jnp.tile(base, (3, 1))                  # 30 rows, 3x dups
        labs = jnp.arange(30, dtype=jnp.int32) % 7
        store = MemoryStore.create(cfg).calibrate(vecs).write(vecs, labs)
        q = vecs[:6] + 0.02
        mesh = jax.make_mesh((8,), ("data",))
        sstore = store.shard(mesh, ("data",))
        assert sstore.capacity == 104, sstore.capacity  # ragged: 13/shard
        eng = RetrievalEngine(cfg.search, backend="mxu")

        # k=50 > 30 valid rows: masked (empty + pad) rows reach the top-k;
        # k_loc = 13 == the full local block, so the merge is exhaustive
        for mode in ("ideal", "two_phase"):
            ref = RetrievalEngine(cfg.search, backend="ref").search(
                store, q, SearchRequest(mode=mode, k=50))
            assert np.isneginf(np.asarray(ref.votes)).any(), mode
            outs = {}
            for tag, fmr in (("fused", 1), ("dense", 1 << 30)):
                req = SearchRequest(mode=mode, k=50, fused_min_rows=fmr)
                with mesh:
                    f = jax.jit(lambda st, qq, r=req: eng.search(st, qq, r))
                    outs[tag] = f(sstore, q)
                    hlo = jax.jit(lambda st, qq, r=req: eng.search(
                        st, qq, r).votes).lower(sstore, q).compile().as_text()
                hc.assert_fused_tag(hlo, tag == "fused")
            for tag in ("fused", "dense"):
                for key in ("votes", "dist", "indices", "labels"):
                    np.testing.assert_array_equal(
                        np.asarray(getattr(ref, key)),
                        np.asarray(getattr(outs[tag], key)),
                        err_msg=f"{mode}/{tag}/{key}")

        # the 'fused' backend fuses unconditionally (no threshold), and the
        # engine-level field steers the default dispatch
        with mesh:
            hlo = jax.jit(lambda st, qq: RetrievalEngine(
                cfg.search, backend="fused").search(
                    st, qq, SearchRequest(mode="ideal", k=13)).votes
                ).lower(sstore, q).compile().as_text()
            hc.assert_fused_tag(hlo, True)
            hlo = jax.jit(lambda st, qq: RetrievalEngine(
                cfg.search, backend="mxu", fused_min_rows=13).search(
                    st, qq, SearchRequest(mode="two_phase", k=13)).votes
                ).lower(sstore, q).compile().as_text()
            hc.assert_fused_tag(hlo, True)
        print("SHARDED-FUSED-OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED-FUSED-OK" in proc.stdout


# ---------------------------------------------------------------------------
# (c) Two-phase recall@k == 1.0 vs full search on small clustered stores.
# ---------------------------------------------------------------------------


def _clustered_store(key, n_way=10, k_shot=4, n_query=2, dim=32):
    kc, ks, kq = jax.random.split(jax.random.PRNGKey(key), 3)
    centers = jax.random.normal(kc, (n_way, dim)) * 2.2
    s_lab = jnp.repeat(jnp.arange(n_way), k_shot)
    q_lab = jnp.repeat(jnp.arange(n_way), n_query)
    s = centers[s_lab] + 0.9 * jax.random.normal(ks, (len(s_lab), dim))
    q = centers[q_lab] + 0.9 * jax.random.normal(kq, (len(q_lab), dim))
    lo, hi = float(s.min()), float(s.max())
    to_int = lambda x, lv: jnp.clip(jnp.round(
        (x - lo) / (hi - lo) * (lv - 1)), 0, lv - 1).astype(jnp.int32)
    return to_int(q, 4), to_int(s, 25)  # mtmc cl=8 -> 25 levels


@pytest.mark.parametrize("key", [0, 1, 2])
def test_two_phase_recall_at_k_is_one(key):
    cfg = SearchConfig("mtmc", cl=8, mode="avss", mcam=MCAMConfig(),
                       use_kernel="ref")
    qv, sv = _clustered_store(key)
    eng = RetrievalEngine(cfg, backend="ref")
    full = eng.full(qv, sv)
    full_best = np.asarray(avss_lib.best_support(full))
    tp = eng.two_phase(qv, sv, k=16)
    idx = np.asarray(tp["indices"])
    # recall@k: the full-search winner makes the shortlist for every query
    in_shortlist = [full_best[b] in idx[b] for b in range(len(full_best))]
    assert float(np.mean(in_shortlist)) == 1.0
    # and the two-phase 1-NN decision matches the full search exactly
    best = np.asarray(avss_lib.best_support(tp))
    tp_best = idx[np.arange(len(best)), best]
    recall = float((full_best == tp_best).mean())
    assert recall == 1.0, recall


# ---------------------------------------------------------------------------
# (b) Sharded two-phase == single-device two-phase, bit for bit, on a forced
# 8-device host mesh (subprocess: XLA_FLAGS must precede jax import).
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_two_phase_bit_identical():
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.avss import SearchConfig
        from repro.core.mcam import MCAMConfig
        from repro.engine import RetrievalEngine

        cfg = SearchConfig("mtmc", cl=8, mode="avss", mcam=MCAMConfig(),
                           use_kernel="ref")
        N, B, d = 256, 6, 48
        sv = jax.random.randint(jax.random.PRNGKey(0), (N, d), 0,
                                cfg.enc.levels)
        qv = jax.random.randint(jax.random.PRNGKey(1), (B, d), 0, 4)
        eng = RetrievalEngine(cfg, backend="ref")
        tp = eng.two_phase(qv, sv, k=48)
        for shape, axes in [((8,), ("data",)),
                            ((4, 2), ("data", "model"))]:
            mesh = jax.make_mesh(shape, ("data", "model")[:len(shape)])
            svs = jax.device_put(sv, NamedSharding(mesh, P(axes)))
            with mesh:
                sh = eng.sharded_two_phase(qv, svs, mesh, axes=axes, k=48)
            for key in ("votes", "dist", "indices"):
                np.testing.assert_array_equal(
                    np.asarray(tp[key]), np.asarray(sh[key]),
                    err_msg=f"{shape}/{key}")
        # adversarial tie stress: every row duplicated once per shard, so
        # every distance appears 8x and the (distance, global row) ordering
        # is the ONLY thing keeping shards in agreement
        sv2 = jnp.concatenate([sv[:32]] * 8, 0)
        tp2 = eng.two_phase(qv, sv2, k=40)
        mesh = jax.make_mesh((8,), ("data",))
        svs2 = jax.device_put(sv2, NamedSharding(mesh, P("data")))
        with mesh:
            sh2 = eng.sharded_two_phase(qv, svs2, mesh, axes=("data",),
                                        k=40)
        for key in ("votes", "dist", "indices"):
            np.testing.assert_array_equal(np.asarray(tp2[key]),
                                          np.asarray(sh2[key]), err_msg=key)

        # validity mask: parity must survive phase-1 masking too
        valid = (jnp.arange(N) % 5) != 0
        tpm = eng.two_phase(qv, sv, k=48, valid=valid)
        shm = eng.sharded_two_phase(
            qv, jax.device_put(sv, NamedSharding(mesh, P("data"))),
            mesh, axes=("data",), k=48,
            valid=jax.device_put(valid, NamedSharding(mesh, P("data"))))
        for key in ("votes", "dist", "indices"):
            np.testing.assert_array_equal(np.asarray(tpm[key]),
                                          np.asarray(shm[key]),
                                          err_msg=f"mask/{key}")

        # memory-level: distributed exact search == local two-phase search
        from repro.core import memory as mem
        from repro.core.memory import MemoryConfig
        mcfg = MemoryConfig(capacity=128, dim=24,
                            search=SearchConfig("mtmc", cl=8, mode="avss",
                                                use_kernel="ref"))
        vecs = jax.random.normal(jax.random.PRNGKey(5), (96, 24))
        labs = jnp.arange(96, dtype=jnp.int32) % 7
        state = mem.init_memory(mcfg)
        state = mem.calibrate(state, vecs, mcfg)
        state = mem.write(state, vecs, labs, mcfg)
        queries = vecs[:5] + 0.05 * jax.random.normal(
            jax.random.PRNGKey(6), (5, 24))
        local = mem.search(state, queries, mcfg, two_phase=True, k=16)
        mesh = jax.make_mesh((8,), ("data",))
        sstate = mem.shard_state(state, mesh, ("data",))
        with mesh:
            dist = mem.distributed_search(sstate, queries, mcfg, mesh,
                                          axes=("data",), k=16)
        for key in ("votes", "dist", "indices", "labels"):
            np.testing.assert_array_equal(np.asarray(local[key]),
                                          np.asarray(dist[key]), err_msg=key)

        # unified API: engine.search over a shard-aware MemoryStore must be
        # bit-identical to the pre-redesign two_phase/sharded_two_phase --
        # including a RAGGED (non-divisible) split: capacity 100 over 8
        # shards pads to 104 rows with label -1 pad rows
        from repro.engine import MemoryStore, SearchRequest
        rcfg = MemoryConfig(capacity=100, dim=24,
                            search=SearchConfig("mtmc", cl=8, mode="avss",
                                                use_kernel="ref"))
        rvecs = jax.random.normal(jax.random.PRNGKey(9), (100, 24))
        rlabs = jnp.arange(100, dtype=jnp.int32) % 11
        rstore = MemoryStore.create(rcfg).calibrate(rvecs).write(rvecs,
                                                                 rlabs)
        rq = rvecs[:6] + 0.03 * jax.random.normal(jax.random.PRNGKey(10),
                                                  (6, 24))
        reng = RetrievalEngine(rcfg.search)
        req = SearchRequest(mode="two_phase", k=32)
        # pre-redesign reference: raw-array two_phase + global label gather
        rqv = rstore.quantize_queries(rq)
        pre = reng.two_phase(rqv, rstore.values, k=32,
                             valid=rstore.labels >= 0)
        pre_labels = rstore.labels[pre["indices"]]
        new_local = reng.search(rstore, rq, req)
        np.testing.assert_array_equal(np.asarray(pre["votes"]),
                                      np.asarray(new_local.votes))
        np.testing.assert_array_equal(np.asarray(pre_labels),
                                      np.asarray(new_local.labels))
        mesh = jax.make_mesh((8,), ("data",))
        rsharded = rstore.shard(mesh, ("data",))
        assert rsharded.capacity == 104, rsharded.capacity
        with mesh:
            new_sh = reng.search(rsharded, rq, req)
        for key in ("votes", "dist", "indices", "labels"):
            np.testing.assert_array_equal(
                np.asarray(getattr(new_local, key)),
                np.asarray(getattr(new_sh, key)), err_msg=f"ragged/{key}")
        print("SHARDED-BIT-IDENTICAL")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED-BIT-IDENTICAL" in proc.stdout
