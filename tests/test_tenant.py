"""Multi-tenant serving parity suite (repro/engine/tenant.py, PR 9).

The tentpole's contract: `RetrievalEngine.search_tenants` over a stacked
`TenantStore` is BIT-IDENTICAL per tenant to solo `engine.search` over
each tenant's own store -- on every mode x backend x packed/unpacked
route, including the noisy paths (whose counter-hash noise is keyed on
the query's rank WITHIN its tenant group, not its batch position) -- and
ONE jitted search program serves any tenant count (one cache entry per
distinct batch shape, none per tenant, none per write).

Fixture geometry (mirrors the shard-parity tests): 5 tenants with ragged
capacities, one tenant left EMPTY (create + calibrate, no writes; its
queries must predict the -1 sentinel), one tie-heavy tenant (duplicated
rows force (distance, index) lexicographic rank to carry the parity),
and masked label -1 rows placed to land inside the top-k.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.avss import SearchConfig
from repro.core.memory import MemoryConfig
from repro.engine import (MemoryStore, RetrievalEngine, SearchRequest,
                          TenantStore, tenant_query_rank)

CAPS = (12, 7, 16, 5, 9)
EMPTY = 3        # tenant created+calibrated but never written
TIE_HEAVY = 2    # tenant whose rows repeat 4x (lexicographic tie-break)
DIM = 20
K = 4


def _cfg():
    return SearchConfig("mtmc", cl=4, mode="avss", use_kernel="ref")


@pytest.fixture(scope="module")
def tenant_fixture():
    """(stores, tstore, queries, tenant_ids): the 5-tenant stack above
    plus an interleaved query batch hitting every tenant (with repeats,
    so the per-tenant noise rank differs from the batch position)."""
    cfg = _cfg()
    rng = np.random.default_rng(0)
    stores = []
    for i, c in enumerate(CAPS):
        if i == EMPTY:
            mc = MemoryConfig(capacity=c, dim=DIM, search=cfg)
            sample = jnp.asarray(rng.normal(size=(8, DIM)), jnp.float32)
            stores.append(MemoryStore.create(mc).calibrate(sample))
            continue
        v = rng.integers(0, cfg.enc.levels, size=(c, DIM))
        if i == TIE_HEAVY:
            v = np.concatenate([v[:4]] * 4)[:c]
        lab = rng.integers(0, 5, size=(c,))
        lab[::4] = -1            # masked rows inside the top-k
        stores.append(MemoryStore.from_quantized(
            jnp.asarray(v), jnp.asarray(lab), cfg))
    tstore = TenantStore.stack(stores)
    tenant_ids = jnp.array([0, 2, 1, 0, 2, 4, 2, 3, 0, 1], jnp.int32)
    queries = jnp.asarray(rng.integers(0, 4, size=(10, DIM)), jnp.int32)
    return stores, tstore, queries, tenant_ids


def _assert_rows_equal(batched, solo, sel, width, mode):
    """Per-tenant rows of the coalesced result == the solo result, and on
    the full mode the pad columns beyond the tenant's capacity are fully
    masked (-inf votes)."""
    for leaf in ("votes", "dist", "indices", "labels"):
        b = getattr(batched, leaf)
        if b is None:           # full mode has no indices/labels
            assert getattr(solo, leaf) is None
            continue
        np.testing.assert_array_equal(
            np.asarray(b[sel][:, :width]), np.asarray(getattr(solo, leaf)),
            err_msg=f"{mode}: {leaf}")
    if mode == "full" and batched.votes.shape[1] > width:
        assert bool((batched.votes[sel][:, width:] == -jnp.inf).all())


@pytest.mark.parametrize("backend", ["ref", "mxu", "fused"])
@pytest.mark.parametrize("mode", ["full", "two_phase", "ideal"])
@pytest.mark.parametrize("packed", [True, False])
def test_search_tenants_bit_parity(tenant_fixture, mode, backend, packed):
    stores, tstore, queries, tenant_ids = tenant_fixture
    if not packed:
        tstore = dataclasses.replace(tstore, proj_packed=None)
        stores = [dataclasses.replace(s, proj_packed=None) for s in stores]
    eng = RetrievalEngine(_cfg())
    req = SearchRequest(mode=mode, k=K, backend=backend)
    res = eng.search_tenants(tstore, queries, tenant_ids, req)
    tids = np.asarray(tenant_ids)
    for t in range(len(CAPS)):
        sel = np.where(tids == t)[0]
        if not len(sel):
            continue
        solo = eng.search(stores[t], queries[jnp.asarray(sel)], req)
        width = CAPS[t] if mode == "full" else min(K, CAPS[t])
        _assert_rows_equal(res, solo, sel, width, mode)


def test_empty_tenant_predicts_sentinel(tenant_fixture):
    _, tstore, queries, tenant_ids = tenant_fixture
    eng = RetrievalEngine(_cfg())
    res = eng.search_tenants(tstore, queries, tenant_ids,
                             SearchRequest(mode="two_phase", k=K))
    preds = np.asarray(res.predict())
    empty = np.asarray(tenant_ids) == EMPTY
    assert (preds[empty] == -1).all()
    assert (preds[~empty] >= -1).all()      # others may still abstain


def test_k_beyond_tenant_capacity_pads_masked(tenant_fixture):
    """k larger than the smallest tenant's capacity: the extra shortlist
    columns must be masked pads (-inf votes, label -1) -- never rows
    leaked from another tenant."""
    stores, tstore, queries, tenant_ids = tenant_fixture
    eng = RetrievalEngine(_cfg())
    k = min(CAPS) + 2
    res = eng.search_tenants(tstore, queries, tenant_ids,
                             SearchRequest(mode="two_phase", k=k))
    tids = np.asarray(tenant_ids)
    for t in (np.argmin(CAPS), EMPTY):
        sel = np.where(tids == t)[0]
        over = res.labels[sel][:, CAPS[t]:] if t != EMPTY else \
            res.labels[sel]
        assert bool((over == -1).all())
        votes_over = res.votes[sel][:, CAPS[t]:] if t != EMPTY else \
            res.votes[sel]
        assert bool((votes_over == -jnp.inf).all())


def test_noiseless_parity(tenant_fixture):
    """noisy=False route (no counter hash at all) stays bit-identical."""
    stores, tstore, queries, tenant_ids = tenant_fixture
    eng = RetrievalEngine(_cfg())
    req = SearchRequest(mode="two_phase", k=K, noisy=False)
    res = eng.search_tenants(tstore, queries, tenant_ids, req)
    tids = np.asarray(tenant_ids)
    for t in range(len(CAPS)):
        sel = np.where(tids == t)[0]
        solo = eng.search(stores[t], queries[jnp.asarray(sel)], req)
        _assert_rows_equal(res, solo, sel, min(K, CAPS[t]), "two_phase")


def test_tenant_query_rank():
    ranks = tenant_query_rank(jnp.array([0, 2, 1, 0, 2, 4, 2, 3, 0, 1]))
    assert ranks.tolist() == [0, 0, 0, 1, 1, 0, 2, 0, 2, 1]
    assert ranks.dtype == jnp.uint32


def test_stack_round_trip(tenant_fixture):
    stores, tstore, _, _ = tenant_fixture
    assert tstore.n_tenants == len(CAPS)
    assert tstore.n_pad == max(CAPS)
    assert tstore.capacities == CAPS
    for i, s in enumerate(stores):
        t = tstore.tenant(i)
        for leaf in ("values", "proj", "proj_packed", "s_grid", "labels",
                     "size", "lo", "hi"):
            np.testing.assert_array_equal(np.asarray(getattr(t, leaf)),
                                          np.asarray(getattr(s, leaf)),
                                          err_msg=f"tenant {i}: {leaf}")
        assert t.cfg == s.cfg and t.calibrated == s.calibrated


def test_stack_rejects_mismatched_stores():
    cfg = _cfg()
    other = SearchConfig("mtmc", cl=8, mode="avss", use_kernel="ref")
    a = MemoryStore.from_quantized(jnp.zeros((2, 8), jnp.int32),
                                   jnp.array([0, 1]), cfg)
    b = MemoryStore.from_quantized(jnp.zeros((2, 8), jnp.int32),
                                   jnp.array([0, 1]), other)
    c = MemoryStore.from_quantized(jnp.zeros((2, 6), jnp.int32),
                                   jnp.array([0, 1]), cfg)
    with pytest.raises(ValueError, match="at least one store"):
        TenantStore.stack([])
    with pytest.raises(ValueError, match="SearchConfig/dim"):
        TenantStore.stack([a, b])
    with pytest.raises(ValueError, match="SearchConfig/dim"):
        TenantStore.stack([a, c])


def test_write_at_matches_solo_write(tenant_fixture):
    stores, tstore, _, _ = tenant_fixture
    rng = np.random.default_rng(7)
    vecs = jnp.asarray(rng.normal(size=(3, DIM)), jnp.float32)
    labs = jnp.array([9, 8, 7])
    t2 = tstore.write_at(EMPTY, vecs, labs).tenant(EMPTY)
    solo = stores[EMPTY].write(vecs, labs)
    for leaf in ("values", "proj", "proj_packed", "s_grid", "labels",
                 "size"):
        np.testing.assert_array_equal(np.asarray(getattr(t2, leaf)),
                                      np.asarray(getattr(solo, leaf)),
                                      err_msg=leaf)


def test_write_at_guards(tenant_fixture):
    stores, tstore, _, _ = tenant_fixture
    vecs = jnp.zeros((2, DIM), jnp.float32)
    labs = jnp.array([1, 2])
    # never-calibrated tenant (from_quantized stores): concrete id raises
    with pytest.raises(ValueError, match="never-calibrated"):
        tstore.write_at(0, vecs, labs)
    # traced id on a partially-calibrated stack raises at trace time
    with pytest.raises(ValueError, match="never-calibrated"):
        jax.jit(lambda ts, t: ts.write_at(t, vecs, labs))(
            tstore, jnp.asarray(EMPTY, jnp.int32))
    # oversize batch on the concrete path
    calibrated = TenantStore.stack([stores[EMPTY], stores[EMPTY]])
    with pytest.raises(AssertionError, match="exceeds"):
        calibrated.write_at(0, jnp.zeros((CAPS[EMPTY] + 1, DIM)),
                            jnp.zeros((CAPS[EMPTY] + 1,), jnp.int32))


def test_single_jit_entry_per_tenant_count(tenant_fixture):
    """ONE compiled search program per batch shape: for each tenant count
    T, repeated calls with fresh stores/queries/tenant_ids of the same
    shape add exactly one cache entry -- no per-tenant or per-write
    retrace. The same mapping feeds the single_jit_entry_across_tenants
    contract invariant (analysis/registry.py)."""
    from functools import partial

    from repro.analysis import hlo_contracts as hc

    cfg = _cfg()
    eng = RetrievalEngine(cfg)
    req = SearchRequest(mode="two_phase", k=2)

    @partial(jax.jit, static_argnames=("req",))
    def f(ts, q, tids, req):
        return eng.search_tenants(ts, q, tids, req).votes

    def mk_stack(T, seed):
        r = np.random.default_rng(seed)
        return TenantStore.stack([
            MemoryStore.from_quantized(
                jnp.asarray(r.integers(0, cfg.enc.levels, size=(6, 8))),
                jnp.asarray(r.integers(0, 3, size=(6,))), cfg)
            for _ in range(T)])

    entries = {}
    for T in (1, 5, 64):
        before = f._cache_size()
        for trial in range(3):
            r = np.random.default_rng(100 * T + trial)
            ts = mk_stack(T, seed=T + trial)
            q = jnp.asarray(r.integers(0, 4, size=(4, 8)), jnp.int32)
            tids = jnp.asarray(r.integers(0, T, size=(4,)), jnp.int32)
            f(ts, q, tids, req).block_until_ready()
        entries[T] = f._cache_size() - before
    hc.assert_single_jit_entry_across_tenants(entries)
    assert entries == {1: 1, 5: 1, 64: 1}


def test_tenant_server_coalesce_and_write():
    """The launch/serve.py coalescing shell: submit -> flush returns each
    ticket's row bit-identical to the direct coalesced call, and ring
    writes through the server never retrace the search."""
    from repro.launch.serve import TenantServer

    cfg = _cfg()
    rng = np.random.default_rng(3)
    stores = []
    for t in range(3):
        mc = MemoryConfig(capacity=6, dim=DIM, search=cfg)
        emb = jnp.asarray(rng.normal(size=(6, DIM)), jnp.float32)
        stores.append(MemoryStore.create(mc).calibrate(emb).write(
            emb, jnp.asarray(rng.integers(0, 4, size=(6,)))))
    eng = RetrievalEngine(cfg)
    req = SearchRequest(mode="two_phase", k=3)
    server = TenantServer(eng, TenantStore.stack(stores), req)

    q = jnp.asarray(rng.normal(size=(4, DIM)), jnp.float32)
    tids = [1, 0, 2, 1]
    tickets = [server.submit(t, q[i]) for i, t in enumerate(tids)]
    out = server.flush()
    direct = eng.search_tenants(server.tstore, q,
                                jnp.asarray(tids, jnp.int32), req)
    for i in tickets:
        np.testing.assert_array_equal(np.asarray(out[i].labels[0]),
                                      np.asarray(direct.labels[i]))
    entries = server.cache_entries()
    server.write(0, jnp.asarray(rng.normal(size=(2, DIM)), jnp.float32),
                 jnp.array([5, 6]))
    for i, t in enumerate(tids):
        server.submit(t, q[i])
    server.flush()
    assert server.cache_entries() == entries    # write did not retrace


def _mk_server(seed=7, n_tenants=3):
    from repro.launch.serve import TenantServer
    cfg = _cfg()
    rng = np.random.default_rng(seed)
    stores = []
    for t in range(n_tenants):
        mc = MemoryConfig(capacity=6, dim=DIM, search=cfg)
        emb = jnp.asarray(rng.normal(size=(6, DIM)), jnp.float32)
        stores.append(MemoryStore.create(mc).calibrate(emb).write(
            emb, jnp.asarray(rng.integers(0, 4, size=(6,)))))
    eng = RetrievalEngine(cfg)
    req = SearchRequest(mode="two_phase", k=3)
    return (TenantServer(eng, TenantStore.stack(stores), req), eng, req,
            rng)


def test_tenant_server_flush_empty_queue():
    """flush() with nothing queued is a no-op: returns {} and never
    touches the compiled search (no zero-row batch dispatch)."""
    server, _, _, _ = _mk_server()
    before = server.cache_entries()
    assert server.flush() == {}
    assert server.flush() == {}                # idempotent
    assert server.cache_entries() == before


def test_tenant_server_interleaved_write_between_submits():
    """A ring write to a tenant BETWEEN submits to that same tenant:
    flush() serves every queued query against the POST-write store (the
    server holds one store; submit enqueues queries, not snapshots), and
    the write does not retrace the compiled search."""
    server, eng, req, rng = _mk_server(seed=8)
    q = jnp.asarray(rng.normal(size=(3, DIM)), jnp.float32)
    t0 = server.submit(1, q[0])
    server.write(1, jnp.asarray(rng.normal(size=(2, DIM)), jnp.float32),
                 jnp.array([8, 9]))
    t1 = server.submit(1, q[1])
    t2 = server.submit(0, q[2])
    entries = server.cache_entries()
    out = server.flush()
    assert sorted(out) == [t0, t1, t2]
    assert server.cache_entries() in (entries, entries + 1)  # shape only
    direct = eng.search_tenants(server.tstore, q,
                                jnp.asarray([1, 1, 0], jnp.int32), req)
    for tk, row in ((t0, 0), (t1, 1), (t2, 2)):
        for f in ("votes", "dist", "indices", "labels"):
            np.testing.assert_array_equal(
                np.asarray(getattr(out[tk], f)[0]),
                np.asarray(getattr(direct, f)[row]), err_msg=f)


def test_tenant_server_duplicate_tenant_ticket_ordering():
    """Many queries for the SAME tenant in one flush: each ticket gets
    ITS OWN query's row back (ticket == batch row), and the per-tenant
    noise rank keys on queue order -- bit-identical to the direct
    coalesced call with the same duplicate tenant_ids batch."""
    server, eng, req, rng = _mk_server(seed=9)
    q = jnp.asarray(rng.normal(size=(5, DIM)), jnp.float32)
    tids = [2, 2, 0, 2, 2]                     # duplicates, interleaved
    tickets = [server.submit(t, q[i]) for i, t in enumerate(tids)]
    assert tickets == [0, 1, 2, 3, 4]          # tickets ARE queue order
    out = server.flush()
    assert sorted(out) == tickets
    direct = eng.search_tenants(server.tstore, q,
                                jnp.asarray(tids, jnp.int32), req)
    for tk in tickets:
        for f in ("votes", "dist", "indices", "labels"):
            np.testing.assert_array_equal(
                np.asarray(getattr(out[tk], f)[0]),
                np.asarray(getattr(direct, f)[tk]), err_msg=f"{tk}:{f}")
    # identical queries to the same tenant must still get DISTINCT noise
    # ranks (queue position), hence independent result rows exist per
    # ticket rather than one shared row object
    same = [server.submit(2, q[0]) for _ in range(3)]
    out2 = server.flush()
    assert sorted(out2) == same and len(out2) == 3
