"""Symbolic VMEM model (repro/analysis/vmem) vs interpret-mode reality.

The model's closed-form per-tile bytes are validated against
`memory_analysis()` of the jitted kernel on single-tile grids: argument +
output bytes must equal the model's `io_block_bytes` -- exactly for
unpacked/unmasked/native configs, within the model's own
`padding_slack_bytes` otherwise. A deterministic config quartet runs in
the fast tier; a hypothesis sweep (slow tier) fuzzes the tiling knobs.
The static gate (`validate_config` + benchmarks/autotune_shortlist
.plan_configs) must accept every real sweep config and reject a
deliberately VMEM-overflowing one before anything lowers.
"""

import itertools
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import cost, vmem
from repro.core.encodings import make_encoding
from repro.kernels import ops as kernel_ops
from repro.kernels.shortlist import lut_shortlist_pallas

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # CI installs it; local may not
    HAVE_HYPOTHESIS = False


def _measured_io_bytes(tile_b, tile_n, k, *, d, masked, use_network,
                       packed_enc=None):
    """Argument + output bytes of the jitted kernel on a single-tile grid
    (B = tile_b, N = the model's effective tile_n), via the one cost
    model's `compiled_memory`."""
    est = vmem.shortlist_vmem(
        tile_b, tile_n, k, width=4 * d,
        pack_bits=packed_enc and kernel_ops.projection_pack_bits(
            packed_enc, jnp.float32),
        masked=masked, use_network=use_network)
    B, N = tile_b, est.tile_n
    sv = jax.random.randint(jax.random.PRNGKey(0), (N, d), 0,
                            (packed_enc.levels if packed_enc else 4))
    qv = jax.random.randint(jax.random.PRNGKey(1), (B, d), 0, 4)
    q1h = kernel_ops.query_onehot(qv, jnp.float32)
    kw = dict(k=k, tile_b=tile_b, tile_n=N, interpret=True,
              use_network=use_network)
    args = []
    if packed_enc is not None:
        proj = kernel_ops.support_projection(sv, packed_enc, jnp.float32)
        args.append(kernel_ops.pack_projection(proj, packed_enc))
        kw["pack_bits"] = kernel_ops.projection_pack_bits(
            packed_enc, jnp.float32)
        fn = lambda q, p, v=None: lut_shortlist_pallas(
            q, None, packed=p, valid=v, **kw)
    else:
        args.append(jnp.asarray(
            jax.random.randint(jax.random.PRNGKey(2), (N, 4 * d), 0, 4),
            jnp.float32))
        fn = lambda q, s, v=None: lut_shortlist_pallas(q, s, valid=v, **kw)
    if masked:
        args.append(jnp.arange(N) % 3 != 0)
    compiled = jax.jit(fn).lower(q1h, *args).compile()
    mem = cost.compiled_memory(compiled)
    # output_size_in_bytes carries the runtime's tuple pointer table (8 B
    # per output leaf on XLA:CPU) on top of the (dist, idx) buffers the
    # model prices -- measure it and take it back out
    leaf_bytes = sum(
        int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
        for s in jax.tree_util.tree_leaves(jax.eval_shape(fn, q1h, *args)))
    table = mem["output_size_in_bytes"] - leaf_bytes
    assert 0 <= table <= 64, (mem, leaf_bytes)
    return (mem["argument_size_in_bytes"]
            + mem["output_size_in_bytes"] - table, est)


def test_vmem_model_exact_on_native_unpacked_unmasked():
    """The anchor: no padding anywhere -> model == measured, byte for
    byte."""
    measured, est = _measured_io_bytes(8, 256, 16, d=48, masked=False,
                                       use_network=False)
    assert est.padding_slack_bytes == 0
    assert measured == est.io_block_bytes


@pytest.mark.parametrize("masked,use_network,packed",
                         [(True, False, False),    # penalty stream pad
                          (False, True, False),    # kp > k output pad
                          (False, False, True),    # packed query-width pad
                          (True, True, True)])     # everything at once
def test_vmem_model_within_padding_slack(masked, use_network, packed):
    enc = make_encoding("mtmc", 8) if packed else None
    measured, est = _measured_io_bytes(8, 256, 16, d=48, masked=masked,
                                       use_network=use_network,
                                       packed_enc=enc)
    assert abs(measured - est.io_block_bytes) <= est.padding_slack_bytes, \
        (measured, est)


if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=20, deadline=None)
    @given(tile_b=st.sampled_from([1, 2, 4, 8]),
           tile_n=st.sampled_from([128, 192, 256, 512]),
           k=st.integers(min_value=1, max_value=32),
           masked=st.booleans(), use_network=st.booleans())
    def test_vmem_model_property_sweep(tile_b, tile_n, k, masked,
                                       use_network):
        measured, est = _measured_io_bytes(tile_b, tile_n, k, d=16,
                                           masked=masked,
                                           use_network=use_network)
        assert abs(measured - est.io_block_bytes) \
            <= est.padding_slack_bytes, (measured, est)
else:                                    # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_vmem_model_property_sweep():
        pass


# ---------------------------------------------------------------------------
# The static gate: real sweep configs pass, an overflowing tile is
# rejected before anything lowers.
# ---------------------------------------------------------------------------


def test_validate_config_accepts_every_real_sweep_config():
    # the FULL autotune grid (benchmarks/autotune_shortlist.FULL) must
    # never be gated out -- its biggest tile is well under 1 MiB
    for tb, tn, kpd in itertools.product((8, 16), (256, 512, 1024),
                                         (128, 256)):
        chk = vmem.validate_config(tb, tn, 64, width=4 * 48, k_pad=kpd,
                                   pack_bits=8, q_dtype_bytes=2)
        assert chk.ok, chk.reason
        assert chk.estimate.total_bytes < vmem.TPU_VMEM_BYTES // 8


def test_validate_config_rejects_vmem_overflow():
    chk = vmem.validate_config(8, 2 ** 19, 16, width=64, pack_bits=8)
    assert not chk.ok
    assert chk.estimate.total_bytes > chk.budget_bytes
    assert "exceeds" in chk.reason and "budget" in chk.reason


def test_validate_config_honours_custom_budget():
    chk = vmem.validate_config(8, 256, 16, width=64, budget_bytes=1)
    assert not chk.ok


def _autotune():
    if ROOT not in sys.path:
        sys.path.insert(0, ROOT)
    import benchmarks.autotune_shortlist as at
    return at


def test_autotune_plan_configs_gates_statically():
    at = _autotune()
    configs, skipped = at.plan_configs((8,), (256, 2 ** 19), (128,),
                                       k=16, width=64, pack_bits=8)
    assert ("default",) in configs       # adaptive tiling always runs
    assert (8, 256, 128) in configs
    assert (8, 2 ** 19, 128) not in configs
    (rec,) = skipped
    assert rec["config"] == f"tb=8,tn={2 ** 19},kp=128"
    assert rec["vmem_bytes"] > rec["budget_bytes"]
    assert "exceeds" in rec["reason"]


def test_autotune_sweep_skips_overflowing_config_end_to_end():
    """The acceptance check: a deliberately VMEM-overflowing tile config
    in the sweep grid is provably skipped -- recorded, never timed."""
    at = _autotune()
    rows, crossover, skipped = at.sweep(
        ns=(512,), tile_bs=(8,), tile_ns=(256, 2 ** 19), k_pads=(128,),
        B=4, D=16, k=16, iters=1)
    bad = f"tb=8,tn={2 ** 19},kp=128"
    assert bad in {s["config"] for s in skipped}
    assert bad not in {r["config"] for r in rows}
    # the surviving grid still timed dense + default + the fitting config
    assert {"dense", "default", "tb=8,tn=256,kp=128"} <= \
        {r["config"] for r in rows}
