"""Train/serve parity: HAT's differentiable episodic forward IS the serving
forward.

The contract (ISSUE 5): `RetrievalEngine.episode_votes` -- the forward
hardware-aware training differentiates through -- produces votes/distances
BIT-IDENTICAL to `engine.search` on a `MemoryStore` programmed with the
same supports, across modes, backends (ref + fused) and sharding. The
straight-through estimators are wrappers around the shared primitives, so
no future engine refactor can silently diverge from training without
failing this file.
"""

import subprocess  # noqa: F401  (parity subprocess pattern lives in test_engine)

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.avss import SearchConfig, class_mean_votes
from repro.core.memory import MemoryConfig
from repro.engine import (MemoryStore, RetrievalEngine, SearchRequest)


def _episode(dim=16, n=12, b=5, seed=0):
    """Float relu'd embeddings standing in for controller outputs."""
    s = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(seed), (n, dim)))
    q = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(seed + 1), (b, dim)))
    labels = jnp.arange(n, dtype=jnp.int32) % 4
    return q, s, labels


def _programmed_store(cfg, q, s, labels, capacity=None):
    """The shared train->write->serve recipe: calibrated on the SAME
    sample the asymmetric trainer quantizer saw (support + query stats),
    which makes quantization bit-identical by construction (shared
    `affine_quantize` / `clip_range`)."""
    return MemoryStore.from_episode(s, q, labels, cfg, capacity=capacity)


@pytest.mark.parametrize("noisy", [False, True])
def test_episode_votes_bit_match_full_search(noisy):
    """Noiseless AND noisy (key=None: serving noise coordinates) episodic
    forwards equal engine.search(mode='full') bit-for-bit."""
    cfg = SearchConfig("mtmc", cl=4, mode="avss", use_kernel="ref")
    eng = RetrievalEngine(cfg)
    q, s, labels = _episode()
    ep = eng.episode_votes(q, s, noisy=noisy)
    store = _programmed_store(cfg, q, s, labels)
    res = eng.search(store, q, SearchRequest(mode="full", noisy=noisy))
    np.testing.assert_array_equal(np.asarray(ep["votes"]),
                                  np.asarray(res.votes))
    np.testing.assert_array_equal(np.asarray(ep["dist"]),
                                  np.asarray(res.dist))
    assert ep["iterations"] == res.iterations


def test_episode_scores_equal_served_class_head():
    """The served per-class head (class_mean_votes over search votes) is
    bit-identical to the in-training episode_scores logits -- so eval
    accuracy through the store EXACTLY matches the in-training eval."""
    cfg = SearchConfig("mtmc", cl=4, mode="avss", use_kernel="ref")
    eng = RetrievalEngine(cfg)
    q, s, labels = _episode()
    scores = eng.episode_scores(q, s, labels, 4, noisy=False)
    store = _programmed_store(cfg, q, s, labels)
    res = eng.search(store, q, SearchRequest(mode="full", noisy=False))
    served = class_mean_votes(res.votes, store.labels, 4)
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(served))


@pytest.mark.parametrize("backend", ["ref", "fused"])
@pytest.mark.parametrize("sharded", [False, True])
def test_episode_votes_match_two_phase_candidates(backend, sharded):
    """Every two-phase candidate's vote equals the episodic forward's vote
    for that support row -- ref and fused backends, sharded store included
    (the acceptance matrix of ISSUE 5)."""
    cfg = SearchConfig("mtmc", cl=4, mode="avss", use_kernel="ref")
    eng = RetrievalEngine(cfg)
    q, s, labels = _episode()
    ep = eng.episode_votes(q, s, noisy=False)
    store = _programmed_store(cfg, q, s, labels)
    if sharded:
        store = store.shard(jax.make_mesh((1,), ("data",)))
    res = eng.search(store, q, SearchRequest(
        mode="two_phase", k=s.shape[0], backend=backend, noisy=False))
    votes = jnp.take_along_axis(ep["votes"], res.indices, axis=1)
    dist = jnp.take_along_axis(ep["dist"], res.indices, axis=1)
    np.testing.assert_array_equal(np.asarray(votes), np.asarray(res.votes))
    np.testing.assert_array_equal(np.asarray(dist), np.asarray(res.dist))


def test_parity_survives_empty_slots():
    """A store with unwritten slots serves the written rows bit-identically
    to the episodic forward (masked rows are -inf/-1, never compared)."""
    cfg = SearchConfig("mtmc", cl=4, mode="avss", use_kernel="ref")
    eng = RetrievalEngine(cfg)
    q, s, labels = _episode(n=7)
    ep = eng.episode_votes(q, s, noisy=False)
    store = _programmed_store(cfg, q, s, labels, capacity=10)
    res = eng.search(store, q, SearchRequest(mode="two_phase", k=10,
                                             noisy=False))
    valid = np.asarray(res.labels) >= 0
    assert valid.sum() == q.shape[0] * 7          # every written row found
    got = np.asarray(res.votes)[valid]
    want = np.asarray(jnp.take_along_axis(
        ep["votes"], jnp.asarray(res.indices), axis=1))[valid]
    np.testing.assert_array_equal(got, want)
    assert np.all(np.isneginf(np.asarray(res.votes)[~valid]))


def test_episode_votes_gradients_flow_and_keyed_noise_refreshes():
    """The engine entry point stays differentiable (STE path) and a PRNG
    key draws a fresh counter-hash noise stream per step."""
    cfg = SearchConfig("mtmc", cl=4, mode="avss", use_kernel="ref")
    eng = RetrievalEngine(cfg)
    q, s, _ = _episode(dim=8, n=6, b=3)

    def loss(qe, se):
        return eng.episode_votes(qe, se, key=jax.random.PRNGKey(7))[
            "votes"].sum()

    gq, gs = jax.grad(loss, argnums=(0, 1))(q, s)
    assert float(jnp.linalg.norm(gq)) > 0
    assert float(jnp.linalg.norm(gs)) > 0
    v1 = eng.episode_votes(q, s, key=jax.random.PRNGKey(1))["votes"]
    v2 = eng.episode_votes(q, s, key=jax.random.PRNGKey(2))["votes"]
    v1b = eng.episode_votes(q, s, key=jax.random.PRNGKey(1))["votes"]
    assert not jnp.array_equal(v1, v2)            # fresh noise per key
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v1b))  # det.


def test_svss_episode_votes_bit_match_full_search():
    """The symmetric mode shares the same contract (STE-encoded query)."""
    cfg = SearchConfig("mtmc", cl=3, mode="svss", use_kernel="ref")
    eng = RetrievalEngine(cfg)
    q, s, labels = _episode(dim=10, n=6, b=3)
    ep = eng.episode_votes(q, s, noisy=False)
    # svss quantizes query and support against the SUPPORT range (fake_quant
    # chain); serve the same way: calibrate on the support sample only
    mcfg = MemoryConfig(capacity=6, dim=10, search=cfg)
    store = MemoryStore.create(mcfg).calibrate(s).write(s, labels)
    qv = store.quantize_queries(jnp.clip(q, store.lo, store.hi))
    res = eng.search(store, qv.astype(jnp.int32),
                     SearchRequest(mode="full", noisy=False))
    np.testing.assert_array_equal(np.asarray(ep["votes"]),
                                  np.asarray(res.votes))


@pytest.mark.slow
def test_launch_hat_two_stage_end_to_end(tmp_path):
    """`launch/train.py --hat` on CPU: two-stage HAT train, the closed
    train->write->serve loop with bit-parity, and checkpoints a separate
    process can serve from (acceptance criterion of ISSUE 5)."""
    from repro.core.memory import MemoryConfig
    from repro.launch.train import train_hat

    out = train_hat(pretrain_steps=4, meta_steps=4, n_way=4, k_shot=3,
                    eval_episodes=2, ckpt_dir=str(tmp_path), log_every=2)
    assert np.isfinite(out["pre_losses"]).all()
    assert np.isfinite(out["meta_losses"]).all()
    assert out["parity"] is True
    # identical forward => identical eval accuracy, exactly
    assert out["served_acc"] == out["train_acc"]
    # the checkpointed store serves bit-identically in a fresh store object
    from repro.configs.omniglot_conv4 import get_smoke_config
    from repro.core.avss import SearchConfig
    from repro.core.hat import HATConfig
    from repro.core.mcam import MCAMConfig
    fsl = get_smoke_config()
    hat_cfg = HATConfig(search=SearchConfig(
        "mtmc", cl=fsl.cl, mode="avss", use_kernel="ref",
        mcam=MCAMConfig(sigma_device=0.15, sigma_read=0.05)))
    n = 4 * 3  # eval_way * k_shot supports
    cfg = MemoryConfig(capacity=n, dim=fsl.embed_dim, search=hat_cfg.search,
                       clip_std=hat_cfg.clip_std)
    restored = MemoryStore.restore(str(tmp_path / "store"), cfg)
    assert restored.calibrated and int(restored.size) == n
    q = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(0),
                                      (3, fsl.embed_dim)))
    eng = RetrievalEngine(hat_cfg.search)
    res = eng.search(restored, q, SearchRequest(mode="two_phase", k=4))
    assert res.predict().shape == (3,)
    assert bool((res.labels >= 0).all())
