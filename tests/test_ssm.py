"""Recurrent blocks: chunkwise mLSTM == per-step; sLSTM/mamba step == seq."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import load_config
from repro.models import ssm as S


def _cfg():
    cfg = load_config("xlstm-350m", smoke=True)
    return dataclasses.replace(cfg, dtype="float32", param_dtype="float32")


@pytest.mark.parametrize("chunk", [1, 4, 16])
@pytest.mark.slow
def test_mlstm_chunkwise_equals_step(chunk):
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    B, T = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.5
    p = S.mlstm_init(key, cfg, jnp.float32)
    y_seq, st_seq = S.mlstm_apply_seq(p, x, cfg, chunk=chunk)
    st = S.mlstm_state_init(cfg, B)
    outs = []
    for t in range(T):
        yt, st = S.mlstm_apply_step(p, x[:, t:t + 1], cfg, st)
        outs.append(yt[:, 0])
    y_step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_step),
                               atol=2e-5)
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(st_seq[k]), np.asarray(st[k]),
                                   atol=2e-5)


@pytest.mark.slow
def test_mlstm_state_carry_across_calls():
    """Two halves with carried state == one full pass."""
    cfg = _cfg()
    p = S.mlstm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, cfg.d_model)) * 0.5
    y_full, _ = S.mlstm_apply_seq(p, x, cfg, chunk=4)
    y1, st = S.mlstm_apply_seq(p, x[:, :8], cfg, chunk=4)
    y2, _ = S.mlstm_apply_seq(p, x[:, 8:], cfg, state=st, chunk=4)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-5)


@pytest.mark.slow
def test_slstm_step_equals_seq():
    cfg = _cfg()
    p = S.slstm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.5
    y_seq, _ = S.slstm_apply_seq(p, x, cfg)
    st = S.slstm_state_init(cfg, B)
    outs = []
    for t in range(T):
        yt, st = S.slstm_apply_step(p, x[:, t:t + 1], cfg, st)
        outs.append(yt[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(y_seq), atol=1e-5)


@pytest.mark.slow
def test_mamba_step_equals_seq():
    cfg = dataclasses.replace(load_config("hymba-1.5b", smoke=True),
                              dtype="float32", param_dtype="float32")
    p = S.mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.5
    y_seq, st_seq = S.mamba_apply_seq(p, x, cfg)
    st = S.mamba_state_init(cfg, B)
    outs = []
    for t in range(T):
        yt, st = S.mamba_apply_step(p, x[:, t:t + 1], cfg, st)
        outs.append(yt[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(y_seq), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_seq["h"]), np.asarray(st["h"]),
                               atol=2e-5)


def test_mlstm_long_decay_stability():
    """No NaN/inf after long sequences (stabilised gating)."""
    cfg = _cfg()
    p = S.mlstm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, cfg.d_model)) * 2.0
    y, st = S.mlstm_apply_seq(p, x, cfg, chunk=64)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(st["C"])).all()
