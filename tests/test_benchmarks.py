"""Benchmark harness contracts: the dry-run artifacts CI gates on.

`benchmarks.autotune_shortlist --dry-run` is the fast-job parity +
regression gate for the fused shortlist; downstream consumers (the CI
badge, `--retrieval-fused-min-rows`, benchmarks/run.py) read its JSON, so
the schema is pinned here.
"""

import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_autotune_shortlist_dry_run_schema(tmp_path):
    out = tmp_path / "autotune_shortlist.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.autotune_shortlist",
         "--dry-run", "--out", str(out)],
        capture_output=True, text=True, timeout=300, env=env, cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-3000:]
    doc = json.loads(out.read_text())

    assert doc["generated_by"] == "benchmarks.autotune_shortlist --dry-run"
    assert doc["backend"] in ("cpu", "tpu", "gpu")
    assert doc["measurement"] in ("pallas-interpret", "compiled")
    swept_ns = doc["params"]["ns"]
    assert swept_ns, "dry sweep must cover at least one support count"

    # fused_min_rows: the measured crossover -- a swept N, or None when
    # fused never beat dense (both are valid outcomes; absence is not)
    assert "fused_min_rows" in doc
    fmr = doc["fused_min_rows"]
    assert fmr is None or fmr in swept_ns, fmr

    # skipped_configs: the static VMEM gate's rejections (analysis/vmem.py)
    # -- present (possibly empty), never leaking into the timed rows
    assert isinstance(doc["skipped_configs"], list)
    assert doc["skipped_configs"] == [], \
        "dry-sweep tiles fit VMEM comfortably; a rejection is a model bug"

    # rows: one dense row per N plus >= 1 fused config row, each timed
    rows = doc["rows"]
    for n in swept_ns:
        mine = [r for r in rows if r["n"] == n]
        configs = {r["config"] for r in mine}
        assert "dense" in configs and "default" in configs, configs
        for r in mine:
            assert r["us"] > 0, r
            if r["config"] != "dense":
                assert r["speedup_vs_dense"] > 0, r
