"""Benchmark harness contracts: the dry-run artifacts CI gates on.

`benchmarks.autotune_shortlist --dry-run` is the fast-job parity +
regression gate for the fused shortlist; downstream consumers (the CI
badge, `--retrieval-fused-min-rows`, benchmarks/run.py) read its JSON, so
the schema is pinned here. The multi-tenant budget test pins the
serving-scale wall-clock contract: a 64-tenant coalesced search must
stay inside a fixed CPU-interpret ceiling, which a per-tenant retrace
(the failure mode the one-jit-entry invariant guards) blows by orders
of magnitude.
"""

import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_autotune_shortlist_dry_run_schema(tmp_path):
    out = tmp_path / "autotune_shortlist.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.autotune_shortlist",
         "--dry-run", "--out", str(out)],
        capture_output=True, text=True, timeout=300, env=env, cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-3000:]
    doc = json.loads(out.read_text())

    assert doc["generated_by"] == "benchmarks.autotune_shortlist --dry-run"
    assert doc["backend"] in ("cpu", "tpu", "gpu")
    assert doc["measurement"] in ("pallas-interpret", "compiled")
    swept_ns = doc["params"]["ns"]
    assert swept_ns, "dry sweep must cover at least one support count"

    # fused_min_rows: the measured crossover -- a swept N, or None when
    # fused never beat dense (both are valid outcomes; absence is not)
    assert "fused_min_rows" in doc
    fmr = doc["fused_min_rows"]
    assert fmr is None or fmr in swept_ns, fmr

    # skipped_configs: the static VMEM gate's rejections (analysis/vmem.py)
    # -- present (possibly empty), never leaking into the timed rows
    assert isinstance(doc["skipped_configs"], list)
    assert doc["skipped_configs"] == [], \
        "dry-sweep tiles fit VMEM comfortably; a rejection is a model bug"

    # rows: one dense row per N plus >= 1 fused config row, each timed
    rows = doc["rows"]
    for n in swept_ns:
        mine = [r for r in rows if r["n"] == n]
        configs = {r["config"] for r in mine}
        assert "dense" in configs and "default" in configs, configs
        for r in mine:
            assert r["us"] > 0, r
            if r["config"] != "dense":
                assert r["speedup_vs_dense"] > 0, r


def test_tenant_batch_under_wall_clock_ceiling():
    """64-tenant coalesced serving budget on CPU interpret.

    One compiled `search_tenants` program is reused across repeated
    batches over a 64-tenant stack; after the first (traced) call, the
    steady-state per-batch wall clock must stay under a generous fixed
    ceiling. An accidental per-tenant retrace -- the regression the
    single_jit_entry_across_tenants invariant pins statically -- costs a
    fresh trace+compile per batch (hundreds of ms here), so it blows
    this budget by orders of magnitude while honest interpret-mode
    slowness does not.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.avss import SearchConfig
    from repro.engine import (MemoryStore, RetrievalEngine, SearchRequest,
                              TenantStore)

    cfg = SearchConfig("mtmc", cl=4, mode="avss", use_kernel="ref")
    rng = np.random.default_rng(0)
    stores = [MemoryStore.from_quantized(
        jnp.asarray(rng.integers(0, cfg.enc.levels, size=(8, 12))),
        jnp.asarray(rng.integers(0, 4, size=(8,))), cfg)
        for _ in range(64)]
    tstore = TenantStore.stack(stores)
    eng = RetrievalEngine(cfg)
    req = SearchRequest(mode="two_phase", k=4)
    f = jax.jit(lambda ts, q, i: eng.search_tenants(ts, q, i, req).labels)

    def batch(seed):
        r = np.random.default_rng(seed)
        return (jnp.asarray(r.integers(0, 4, size=(8, 12)), jnp.int32),
                jnp.asarray(r.integers(0, 64, size=(8,)), jnp.int32))

    f(*(tstore,) + batch(0)).block_until_ready()      # trace + compile
    t0 = time.perf_counter()
    iters = 5
    for i in range(1, iters + 1):                      # fresh data, same
        f(*(tstore,) + batch(i)).block_until_ready()   # compiled program
    per_batch = (time.perf_counter() - t0) / iters
    # steady state is ~ms on this container; 2 s absorbs CI jitter while
    # a per-batch retrace (>100 ms compile alone) still fails loudly
    assert per_batch < 2.0, \
        f"64-tenant coalesced batch took {per_batch:.2f}s steady-state " \
        f"(ceiling 2.0s): per-tenant retrace or interpret blowup"


def test_bench_router_dry_run_gate():
    """`benchmarks.bench_router --dry-run` is the fast-job routing gate:
    a shrunken recall/latency sweep whose routed-parity asserts (routed ==
    brute force restricted to the visited shards, nprobe=S byte-identical
    to the exhaustive program) run inside the subprocess. Rows must carry
    the shared name,us,derived CSV shape with a recall= field."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_router", "--dry-run"],
        capture_output=True, text=True, timeout=480, env=env, cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-3000:]
    lines = [l for l in proc.stdout.splitlines() if l.startswith("router/")]
    assert any("exhaustive" in l for l in lines)
    assert all("recall=" in l.split(",", 2)[2] for l in lines)
    assert "dry-run OK" in proc.stdout
