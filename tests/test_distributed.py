"""Distribution features, run in subprocesses with fake host devices
(XLA_FLAGS must be set before jax import, so these cannot run in-process)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

# every test here pays a fresh subprocess jax init (~10s) plus multi-device
# compiles -- full tier only
pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, n_devices: int = 8, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_train_step_on_multi_device_mesh():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import load_config
        from repro.configs.base import ShapeConfig, TrainConfig
        from repro.launch import steps as steps_lib
        from repro.models import transformer as tfm
        from repro.models.sharding import rules_for_mesh, active_mesh
        from repro.launch.dryrun import _with_shardings

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = rules_for_mesh(mesh)
        cfg = load_config("starcoder2-3b", smoke=True)
        tc = TrainConfig(learning_rate=1e-3)
        with mesh, active_mesh(mesh, rules):
            step, opt = steps_lib.make_train_step(cfg, tc, rules)
            params = tfm.init(jax.random.PRNGKey(0), cfg)
            p_shard = steps_lib.param_shardings(cfg, mesh, rules)
            params = jax.tree_util.tree_map(jax.device_put, params, p_shard)
            opt_state = opt.init(params)
            key = jax.random.PRNGKey(1)
            b = {"tokens": jax.random.randint(key, (2, 4, 16), 0, 256),
                 "labels": jax.random.randint(key, (2, 4, 16), 0, 256)}
            jstep = jax.jit(step)
            losses = []
            for i in range(3):
                params, opt_state, m = jstep(params, opt_state, b)
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses  # same batch -> must improve
        print("LOSSES", losses)
    """)
    assert "LOSSES" in out


def test_dryrun_cell_small_mesh():
    """The dry-run machinery itself on an 8-device (2,2,2) pod mesh."""
    out = run_py("""
        import jax, json, numpy as np
        import repro.launch.mesh as mesh_lib
        # shrink the production mesh for the 8-device test environment
        mesh_lib.make_production_mesh = (
            lambda multi_pod=False: jax.make_mesh(
                (2, 2, 2) if multi_pod else (4, 2),
                ("pod", "data", "model") if multi_pod else ("data", "model")))
        import repro.launch.dryrun as dr
        dr.make_production_mesh = mesh_lib.make_production_mesh
        import repro.configs as C
        import dataclasses
        C.SHAPES = dict(C.SHAPES)
        from repro.configs.base import ShapeConfig
        C.SHAPES["train_4k"] = ShapeConfig("train_4k", 64, 8, "train", 4)
        dr.SHAPES = C.SHAPES
        real_load = C.load_config
        def fake_load(arch, smoke=False):
            return real_load(arch, smoke=True)
        dr.load_config = fake_load
        rec = dr.run_cell("deepseek-moe-16b", "train_4k", multi_pod=True)
        assert rec["status"] == "ok", rec
        assert rec["flops_per_device"] > 0
        assert rec["roofline"]["dominant"] in ("compute","memory","collective")
        print("REC", rec["roofline"]["dominant"])
    """, n_devices=8)
    assert "REC" in out


def test_pipeline_parallel_matches_sequential():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime.pipeline import pipeline_apply

        mesh = jax.make_mesh((4,), ("pipe",))
        S, M, mb, d = 4, 6, 3, 8
        keys = jax.random.split(jax.random.PRNGKey(0), S)
        Ws = jnp.stack([jax.random.normal(k, (d, d)) / np.sqrt(d)
                        for k in keys])
        x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
        stage = lambda W, h: jnp.tanh(h @ W)
        out = pipeline_apply(stage, Ws, x, mesh, axis="pipe")
        ref = x
        for i in range(S):
            ref = jnp.tanh(ref @ Ws[i])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print("PIPELINE-OK")
    """, n_devices=4)
    assert "PIPELINE-OK" in out


def test_elastic_checkpoint_across_mesh_shapes():
    """Save sharded on (4,) devices, restore onto (8,)-device sharding."""
    import tempfile
    tmp = tempfile.mkdtemp()
    run_py(f"""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save
        mesh = jax.make_mesh((4,), ("data",))
        w = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(16, 4),
                           NamedSharding(mesh, P("data", None)))
        save({tmp!r}, 1, {{"w": w}})
        print("SAVED")
    """, n_devices=4)
    out = run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import restore
        mesh = jax.make_mesh((8,), ("data",))
        sh = {{"w": NamedSharding(mesh, P("data", None))}}
        out = restore({tmp!r}, {{"w": jax.ShapeDtypeStruct((16, 4),
                                                           jnp.float32)}},
                      shardings=sh)
        np.testing.assert_array_equal(
            np.asarray(out["w"]),
            np.arange(64, dtype=np.float32).reshape(16, 4))
        assert len(out["w"].sharding.device_set) == 8
        print("RESTORED-ELASTIC")
    """, n_devices=8)
    assert "RESTORED-ELASTIC" in out


def test_grad_compression_train_step():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import load_config
        from repro.configs.base import TrainConfig
        from repro.launch import steps as steps_lib
        from repro.models import transformer as tfm
        from repro.models.sharding import Rules
        cfg = load_config("starcoder2-3b", smoke=True)
        tc = TrainConfig(learning_rate=1e-3, grad_compression="int8")
        rules = Rules(batch=(), fsdp=(), tensor=(), expert=())
        step, opt = steps_lib.make_train_step(cfg, tc, rules)
        params = tfm.init(jax.random.PRNGKey(0), cfg)
        opt_state = dict(opt.init(params))
        key = jax.random.PRNGKey(1)
        b = {"tokens": jax.random.randint(key, (1, 4, 16), 0, 256),
             "labels": jax.random.randint(key, (1, 4, 16), 0, 256)}
        losses = []
        jstep = jax.jit(step)
        for i in range(4):
            params, opt_state, m = jstep(params, opt_state, b)
            losses.append(float(m["loss"]))
        assert "ef_residual" in opt_state
        assert losses[-1] < losses[0], losses
        print("COMPRESSED-OK", losses)
    """, n_devices=1)
    assert "COMPRESSED-OK" in out
