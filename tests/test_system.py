"""End-to-end behaviour of the paper's system (small synthetic scale).

Validates the paper's HEADLINE CLAIMS directionally:
  * AVSS iteration reductions are exactly 32x (Omniglot geometry) and
    25x (CUB geometry)  -- paper Table 2.
  * MTMC tolerates the bottleneck effect better than B4E at matched
    precision under the noisy MCAM model -- paper Fig. 9 ordering.
  * AVSS accuracy is close to SVSS -- paper Sec. 4.3.
  * The full MANN pipeline (controller embeddings -> memory -> search)
    classifies a synthetic few-shot episode.
"""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import avss as avss_lib
from repro.core import costmodel
from repro.core.avss import SearchConfig
from repro.core.encodings import make_encoding
from repro.core.mcam import MCAMConfig


def test_paper_iteration_reductions():
    """Table 2: Omniglot 64 -> 2 iterations (32x); CUB 500 -> 20 (25x)."""
    omni = make_encoding("mtmc", 32)
    assert avss_lib.search_iterations(48, omni, "svss") == 64
    assert avss_lib.search_iterations(48, omni, "avss") == 2
    cub = make_encoding("mtmc", 25)
    assert avss_lib.search_iterations(480, cub, "svss") == 500
    assert avss_lib.search_iterations(480, cub, "avss") == 20
    # throughput back-solves to the paper's Table 2 numbers
    assert abs(costmodel.throughput_searches_per_s(48, omni, "svss")
               - 312.5) < 1e-6
    assert abs(costmodel.throughput_searches_per_s(48, omni, "avss")
               - 10000.0) < 1e-6
    assert abs(costmodel.throughput_searches_per_s(480, cub, "avss")
               - 1000.0) < 1e-6


def test_paper_capacity_omniglot_fits_block():
    """Sec 4.1: 200-way 10-shot at CL=32 needs up to 128K strings."""
    enc = make_encoding("mtmc", 32)
    strings = costmodel.strings_used(48, enc, n_supports=200 * 10)
    assert strings == 128_000


def _episode_accuracy(cfg: SearchConfig, key=0, n_way=16, k_shot=5,
                      n_query=4, dim=48, sep=2.2, noise=0.9):
    """Synthetic episode in embedding space -> search accuracy."""
    kc, ks, kq = jax.random.split(jax.random.PRNGKey(key), 3)
    centers = jax.random.normal(kc, (n_way, dim)) * sep
    s_lab = jnp.repeat(jnp.arange(n_way), k_shot)
    q_lab = jnp.repeat(jnp.arange(n_way), n_query)
    s = centers[s_lab] + noise * jax.random.normal(ks, (len(s_lab), dim))
    q = centers[q_lab] + noise * jax.random.normal(kq, (len(q_lab), dim))
    lo, hi = float(s.min()), float(s.max())
    enc = cfg.enc
    to_int = lambda x, lv: jnp.clip(jnp.round(
        (x - lo) / (hi - lo) * (lv - 1)), 0, lv - 1).astype(jnp.int32)
    sv = to_int(s, enc.levels)
    qv = to_int(q, 4 if cfg.mode == "avss" else enc.levels)
    res = avss_lib.search_quantized(qv, sv, cfg)
    pred = avss_lib.predict_1nn(res, s_lab)
    return float((pred == q_lab).mean())


def _mean_acc(cfg, n=3, **kw):
    return np.mean([_episode_accuracy(cfg, key=k, **kw) for k in range(n)])


@pytest.mark.slow
def test_mtmc_beats_b4e_under_noise():
    """Fig. 9: at matched quantization levels, MTMC's bottleneck immunity
    beats bit-sliced B4E on the noisy MCAM."""
    mcam = MCAMConfig(sigma_device=0.25, sigma_read=0.1)
    acc_mtmc = _mean_acc(SearchConfig("mtmc", cl=21, mode="avss",
                                      mcam=mcam, use_kernel="ref"))
    acc_b4e = _mean_acc(SearchConfig("b4e", cl=3, mode="avss",
                                     mcam=mcam, use_kernel="ref"))
    assert acc_mtmc >= acc_b4e, (acc_mtmc, acc_b4e)


@pytest.mark.slow
def test_avss_close_to_svss():
    """Sec. 4.3: AVSS trades <~ a few points of accuracy for 32x speed."""
    mcam = MCAMConfig(sigma_device=0.1, sigma_read=0.04)
    acc_svss = _mean_acc(SearchConfig("mtmc", cl=8, mode="svss",
                                      mcam=mcam, use_kernel="ref"))
    acc_avss = _mean_acc(SearchConfig("mtmc", cl=8, mode="avss",
                                      mcam=mcam, use_kernel="ref"))
    assert acc_avss >= acc_svss - 0.15, (acc_svss, acc_avss)
    assert acc_avss > 0.5


@pytest.mark.slow
@pytest.mark.filterwarnings(
    "default:repro\\.core\\.memory:DeprecationWarning")  # legacy-API path
def test_full_mann_pipeline_with_controller(fsl_episode, conv4_embeddings):
    """Conv4 controller (untrained) + memory + AVSS beats chance by a wide
    margin on the procedural Omniglot-like episodes.

    (Historical note: this asserted > 0.4 and failed at 0.35 in the seed --
    the root cause was memory.calibrate quantizing post-ReLU embeddings over
    an un-clamped mu +/- 2.5 sigma range, wasting half of the 4-level query
    range on the empty negative half. Fixed in calibrate; accuracy 0.65.)"""
    from repro.core import memory as mem
    from repro.core.memory import MemoryConfig

    _, s_emb, q_emb = conv4_embeddings
    cfg = MemoryConfig(capacity=64, dim=24,
                       search=SearchConfig("mtmc", cl=8, mode="avss",
                                           use_kernel="ref"))
    state = mem.init_memory(cfg)
    state = mem.calibrate(state, s_emb, cfg)
    state = mem.write(state, s_emb, jnp.asarray(fsl_episode.support_labels),
                      cfg)
    res = mem.search(state, q_emb, cfg)
    pred = mem.predict(res)
    acc = float((pred == jnp.asarray(fsl_episode.query_labels)).mean())
    assert acc > 0.4, acc  # chance = 0.2
