"""Property-based bit-parity: the fused shortlist IS lax.top_k(-dist).

Random sweeps over (tile_b, tile_n, k, k_pad) x {native, network} x
{unpacked, packed} pin kernels/shortlist.py's pre-top-k + bitonic-merge
rewrite to the dense contract -- exact (distance, index) lexicographic
order, SHORTLIST_MASK_PENALTY semantics -- including k > 128, k not a
multiple of the 128 lane width, tie-heavy stores (support rows drawn from
a small pool so duplicated distances dominate), masked rows inside the
top-k, and non-tile-aligned N.

Skip-clean without hypothesis (it is not in the pinned environment; the
deterministic edge-case twins live in tests/test_engine.py).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
from hypothesis import HealthCheck, example, given, settings   # noqa: E402
from hypothesis import strategies as st                        # noqa: E402

from repro.core.encodings import make_encoding                 # noqa: E402
from repro.kernels import ops as kops                          # noqa: E402
from repro.kernels.shortlist import (SHORTLIST_MASK_PENALTY,   # noqa: E402
                                     lut_shortlist_pallas)

ENC = make_encoding("mtmc", 8)


def _check(n, b, d, k, tile_b, tile_n, k_pad, use_network, packed, masked,
           seed):
    rng = np.random.default_rng(seed)
    # tie-heavy: rows drawn from a pool ~n/3 distinct vectors
    pool = rng.integers(0, ENC.levels, (max(1, n // 3), d))
    sv = jnp.asarray(pool[rng.integers(0, pool.shape[0], n)], jnp.int32)
    qv = jnp.asarray(rng.integers(0, 4, (b, d)), jnp.int32)
    valid = jnp.asarray(rng.random(n) > 0.4) if masked else None

    q1h = kops.query_onehot(qv, jnp.float32)
    proj = kops.support_projection(sv, ENC, jnp.float32)
    dense = q1h @ proj.T
    if valid is not None:
        dense = dense + jnp.where(valid, 0.0,
                                  SHORTLIST_MASK_PENALTY)[None, :]
    neg, idx_ref = jax.lax.top_k(-dense, k)

    kw = dict(valid=valid, tile_b=tile_b, tile_n=tile_n, k_pad=k_pad,
              use_network=use_network)
    if packed:
        pk = kops.pack_projection(proj, ENC)
        bits = kops.projection_pack_bits(ENC, proj.dtype)
        dist, idx = lut_shortlist_pallas(q1h, None, k, packed=pk,
                                         pack_bits=bits, **kw)
    else:
        dist, idx = lut_shortlist_pallas(q1h, proj, k, **kw)
    np.testing.assert_array_equal(np.asarray(-neg), np.asarray(dist))
    np.testing.assert_array_equal(np.asarray(idx_ref), np.asarray(idx))


@settings(max_examples=25, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(n=st.integers(1, 160), b=st.integers(1, 4), d=st.integers(2, 12),
       kfrac=st.floats(0.01, 1.0),
       tile_b=st.sampled_from([1, 2, 8, 16]),
       tile_n=st.sampled_from([8, 64, 512]),
       k_pad=st.sampled_from([64, 128, 256]),
       use_network=st.booleans(), packed=st.booleans(),
       masked=st.booleans(), seed=st.integers(0, 2 ** 16))
# k = 131 > 128 and not a lane multiple, masked rows in the top-k, packed
@example(n=150, b=2, d=6, kfrac=0.875, tile_b=8, tile_n=512, k_pad=128,
         use_network=False, packed=True, masked=True, seed=7)
# non-tile-aligned N with a small explicit tile grid, network path
@example(n=45, b=3, d=5, kfrac=0.9, tile_b=2, tile_n=8, k_pad=64,
         use_network=True, packed=False, masked=True, seed=11)
# k == N through the merge path, unpacked native
@example(n=130, b=2, d=4, kfrac=1.0, tile_b=8, tile_n=64, k_pad=128,
         use_network=False, packed=False, masked=False, seed=3)
def test_fused_equals_dense_property(n, b, d, kfrac, tile_b, tile_n, k_pad,
                                     use_network, packed, masked, seed):
    if use_network:
        # the bitonic network is a few hundred eager vector ops per tile:
        # keep its blocks small so the sweep stays fast (the native path
        # explores the large shapes)
        n, b = min(n, 48), min(b, 2)
    k = max(1, min(n, round(kfrac * n)))
    _check(n, b, d, k, tile_b, tile_n, k_pad, use_network, packed, masked,
           seed)
