"""Encoding correctness: paper Table 1 exact values + property tests."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.encodings import (avss_max_lut, avss_sum_lut,
                                  make_encoding)

TABLE1_MTMC = ["00000", "00001", "00011", "00111", "01111", "11111", "11112",
               "11122", "11222", "12222", "22222", "22223", "22233", "22333",
               "23333", "33333"]
TABLE1_B4E = ["00", "01", "02", "03", "10", "11", "12", "13", "20", "21",
              "22", "23", "30", "31", "32", "33"]


def codes_str(enc, v):
    return "".join(str(int(c)) for c in np.asarray(enc.encode(jnp.asarray(v))))


def test_table1_mtmc_cl5():
    enc = make_encoding("mtmc", 5)
    assert enc.levels == 16
    for v, expect in enumerate(TABLE1_MTMC):
        assert codes_str(enc, v) == expect, v


def test_table1_b4e_cl2():
    enc = make_encoding("b4e", 2)
    assert enc.levels == 16
    for v, expect in enumerate(TABLE1_B4E):
        assert codes_str(enc, v) == expect, v


def test_b4we_lengths():
    # paper: B4WE data points are code word lengths 1, 5, 21
    assert make_encoding("b4we", 1).length == 1
    assert make_encoding("b4we", 2).length == 5
    assert make_encoding("b4we", 3).length == 21


@pytest.mark.parametrize("name,cl", [("mtmc", 3), ("mtmc", 8), ("mtmc", 32),
                                     ("b4e", 2), ("b4e", 4), ("sre", 5),
                                     ("b4we", 3)])
def test_decode_roundtrip(name, cl):
    enc = make_encoding(name, cl)
    v = jnp.arange(min(enc.levels, 256))
    assert (enc.decode(enc.encode(v)) == v).all()


@given(cl=st.integers(2, 24), a=st.integers(0, 200), b=st.integers(0, 200))
@settings(max_examples=100, deadline=None)
def test_mtmc_thermometer_l1_identity(cl, a, b):
    """L1 distance in MTMC code space == L1 distance in value space."""
    enc = make_encoding("mtmc", cl)
    a, b = a % enc.levels, b % enc.levels
    ca = np.asarray(enc.encode(jnp.asarray(a)))
    cb = np.asarray(enc.encode(jnp.asarray(b)))
    assert np.abs(ca - cb).sum() == abs(a - b)


@given(cl=st.integers(2, 16), a=st.integers(0, 100), b=st.integers(0, 100))
@settings(max_examples=100, deadline=None)
def test_mtmc_bottleneck_property(cl, a, b):
    """Paper Sec 3.1: |a-b| < CL  =>  max per-word mismatch <= 1."""
    enc = make_encoding("mtmc", cl)
    a, b = a % enc.levels, b % enc.levels
    if abs(a - b) < cl:
        ca = np.asarray(enc.encode(jnp.asarray(a)))
        cb = np.asarray(enc.encode(jnp.asarray(b)))
        assert np.abs(ca - cb).max() <= 1


def test_b4e_small_distance_can_mismatch3():
    """Fig. 3(b): B4E produces mismatch-3 even for close values."""
    enc = make_encoding("b4e", 3)
    ca = np.asarray(enc.encode(jnp.asarray(15)))   # 033
    cb = np.asarray(enc.encode(jnp.asarray(16)))   # 100
    assert np.abs(ca - cb).max() == 3 and abs(15 - 16) == 1


@given(cl=st.integers(2, 16), q=st.integers(0, 3), v=st.integers(0, 100))
@settings(max_examples=100, deadline=None)
def test_avss_identity(cl, q, v):
    """AVSS summed mismatch for MTMC == |CL*q - v| (DESIGN.md; enables the
    MXU LUT formulation)."""
    enc = make_encoding("mtmc", cl)
    v = v % enc.levels
    lut = avss_sum_lut(enc)
    assert lut[q, v] == abs(cl * q - v)


def test_avss_max_lut_bounds():
    enc = make_encoding("mtmc", 8)
    mx = avss_max_lut(enc)
    assert mx.min() >= 0 and mx.max() <= 3
    # exact match of scaled query value -> max mismatch <= 1
    for q in range(4):
        assert mx[q, 8 * q] <= 1
