"""MemoryStore: write-time MCAM layouts, ring wraparound, ragged shards,
and the unified engine.search contract (repro/engine/store.py, api.py).

The store's invariant: `values`, `proj`, `s_grid` and `labels` are written
TOGETHER (one programming operation), so at any point the store's search
artifacts are mutually consistent -- including after ring-buffer
wraparound -- and searches jit against the write-time constants instead of
re-running `layout_support` per call (asserted on compiled HLO below).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import avss as avss_lib
from repro.core.avss import SearchConfig
from repro.core.memory import MemoryConfig
from repro.engine import (MemoryStore, RetrievalEngine, SearchRequest,
                          SearchResult)
from repro.kernels import ops as kernel_ops

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(capacity=32, dim=16, cl=4):
    return MemoryConfig(capacity=capacity, dim=dim,
                        search=SearchConfig("mtmc", cl=cl, mode="avss",
                                            use_kernel="ref"))


def _assert_consistent(store):
    """proj and s_grid must equal the write-time functions of values."""
    enc = store.cfg.search.enc
    sl = store.cfg.search.mcam.string_len
    np.testing.assert_array_equal(
        np.asarray(store.proj),
        np.asarray(kernel_ops.support_projection(store.values, enc)))
    np.testing.assert_array_equal(
        np.asarray(store.s_grid),
        np.asarray(avss_lib.layout_support(store.values, enc, sl)
                   .astype(jnp.int8)))


def test_write_programs_all_layouts():
    cfg = _cfg()
    vecs = jax.random.normal(jax.random.PRNGKey(0), (20, cfg.dim))
    labs = jnp.arange(20, dtype=jnp.int32) % 5
    store = MemoryStore.create(cfg).calibrate(vecs).write(vecs, labs)
    _assert_consistent(store)
    assert int(store.size) == 20
    assert bool(store.valid[:20].all()) and not bool(store.valid[20:].any())


def test_ring_buffer_wraparound_consistency():
    """After writing > capacity vectors, every slot's values/proj/s_grid/
    labels stay mutually consistent, and search results are bit-identical
    to a store programmed directly with the surviving arrangement -- i.e.
    `size` (24 vs 16 here) plays no role in search, which is what makes
    the old `indices < size` validity check (vacuous once size > capacity)
    safe to drop in favour of the label mask."""
    cfg = _cfg(capacity=16, dim=8)
    key = jax.random.PRNGKey(3)
    vecs = jax.random.normal(key, (24, 8))
    labs = jnp.arange(24, dtype=jnp.int32)
    store = MemoryStore.create(cfg).calibrate(vecs)
    store = store.write(vecs[:16], labs[:16]).write(vecs[16:], labs[16:])
    _assert_consistent(store)
    # ring arrangement: slots 0..7 overwritten by vectors 16..23
    np.testing.assert_array_equal(np.asarray(store.labels),
                                  np.r_[np.arange(16, 24), np.arange(8, 16)])
    assert int(store.size) == 24

    # a store programmed with the surviving arrangement in one write
    surviving = jnp.concatenate([vecs[16:24], vecs[8:16]])
    slabs = jnp.concatenate([labs[16:24], labs[8:16]])
    fresh = MemoryStore.create(cfg).calibrate(vecs).write(surviving, slabs)
    np.testing.assert_array_equal(np.asarray(store.values),
                                  np.asarray(fresh.values))

    # values/proj/s_grid/labels being elementwise equal, search parity now
    # only needs to prove `size` (24 vs 16) leaks into no mode's result
    q = vecs[18:22] + 0.01
    eng = RetrievalEngine(cfg.search)
    for mode in ("two_phase", "ideal"):
        req = SearchRequest(mode=mode, k=8)
        f = jax.jit(lambda st, qq: eng.search(st, qq, req))
        a, b = f(store, q), f(fresh, q)
        for key_ in ("votes", "dist", "indices", "labels"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a, key_)), np.asarray(getattr(b, key_)),
                err_msg=f"{mode}/{key_}")


def test_search_result_pytree_roundtrips_jit():
    cfg = _cfg()
    vecs = jax.random.normal(jax.random.PRNGKey(1), (12, cfg.dim))
    labs = jnp.arange(12, dtype=jnp.int32) % 3
    store = MemoryStore.create(cfg).calibrate(vecs).write(vecs, labs)
    eng = RetrievalEngine(cfg.search)
    req = SearchRequest(mode="two_phase", k=4)
    res = jax.jit(lambda st, q: eng.search(st, q, req))(store, vecs[:3])
    assert isinstance(res, SearchResult)
    np.testing.assert_array_equal(np.asarray(res.predict()),
                                  np.asarray(labs[:3]))


def test_from_quantized_matches_raw_two_phase():
    """Unified API over a from_quantized store == raw-array two_phase,
    bit for bit (the old->new parity contract), on every backend."""
    cfg = SearchConfig("mtmc", cl=8, mode="avss", use_kernel="ref")
    sv = jax.random.randint(jax.random.PRNGKey(0), (40, 16), 0,
                            cfg.enc.levels)
    qv = jax.random.randint(jax.random.PRNGKey(1), (3, 16), 0, 4)
    store = MemoryStore.from_quantized(
        sv, jnp.arange(40, dtype=jnp.int32), cfg)
    for backend in ("ref", "mxu", "fused"):
        eng = RetrievalEngine(cfg, backend=backend)
        old = jax.jit(lambda s, q, e=eng: e.two_phase(q, s, k=8))(sv, qv)
        new = jax.jit(lambda st, q, e=eng: e.search(
            st, q, SearchRequest(mode="two_phase", k=8)))(store, qv)
        for key in ("votes", "dist", "indices"):
            np.testing.assert_array_equal(
                np.asarray(old[key]), np.asarray(getattr(new, key)),
                err_msg=f"{backend}/{key}")


def test_store_search_compiles_without_layout_support():
    """Acceptance: the store's grids are write-time constants -- compiling
    a store-based search emits NO layout_support ops (the named_scope tags
    them in HLO), while the raw-array path (read-time layout) does."""
    cfg = _cfg()
    vecs = jax.random.normal(jax.random.PRNGKey(0), (20, cfg.dim))
    labs = jnp.arange(20, dtype=jnp.int32)
    store = MemoryStore.create(cfg).calibrate(vecs).write(vecs, labs)
    eng = RetrievalEngine(cfg.search)
    req = SearchRequest(mode="two_phase", k=8)
    hlo_new = jax.jit(lambda st, q: eng.search(st, q, req).votes) \
        .lower(store, vecs[:2]).compile().as_text()
    assert "layout_support" not in hlo_new
    # control: the raw-array two_phase still lays the store out under jit,
    # proving the scope tag is visible in this build's HLO text
    qv = store.quantize_queries(vecs[:2])
    hlo_old = jax.jit(lambda s, q: eng.two_phase(q, s, k=8)["votes"]) \
        .lower(store.values, qv).compile().as_text()
    assert "layout_support" in hlo_old


@pytest.mark.slow
def test_serve_decode_step_no_layout_under_jit():
    """The real `serve --retrieval` decode step (two-phase engine head)
    compiles with zero layout_support ops: the store programs its grids at
    write time and the jitted decode loop treats them as inputs."""
    from repro.configs import load_config
    from repro.launch import steps as steps_lib
    from repro.models import transformer as tfm
    from repro.models.sharding import Rules

    cfg = load_config("starcoder2-3b", smoke=True)
    rules = Rules(batch=(), fsdp=(), tensor=(), expert=())
    mem_cfg = MemoryConfig(capacity=64, dim=min(48, cfg.d_model),
                           search=SearchConfig("mtmc", cl=8, mode="avss",
                                               use_kernel="ref"))
    vecs = jax.random.normal(jax.random.PRNGKey(7), (32, mem_cfg.dim))
    toks = jax.random.randint(jax.random.PRNGKey(8), (32,), 0,
                              cfg.vocab_size)
    store = MemoryStore.create(mem_cfg).calibrate(vecs).write(vecs, toks)
    engine = RetrievalEngine(mem_cfg.search, backend="ref")
    step = steps_lib.make_serve_step_with_mcam(cfg, rules, mem_cfg,
                                               engine=engine, k=8)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    caches = tfm.init_cache(cfg, 2, 8)
    tok = jnp.zeros((2, 1), jnp.int32)
    hlo = jax.jit(step).lower(params, caches, {"tokens": tok},
                              jnp.int32(0), store).compile().as_text()
    assert "layout_support" not in hlo


@pytest.mark.slow
def test_ragged_3way_split_capacity_100():
    """ROADMAP open item: capacity need not divide the shard count.
    A capacity-100 store sharded 3 ways pads to 102 rows with label -1
    rows that the integer-exact penalty ranks last -- votes/dist/indices/
    labels bit-identical to the unsharded search."""
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.avss import SearchConfig
        from repro.core.memory import MemoryConfig
        from repro.engine import MemoryStore, RetrievalEngine, SearchRequest

        cfg = MemoryConfig(capacity=100, dim=24,
                           search=SearchConfig("mtmc", cl=8, mode="avss",
                                               use_kernel="ref"))
        vecs = jax.random.normal(jax.random.PRNGKey(0), (90, 24))
        labs = jnp.arange(90, dtype=jnp.int32) % 9
        store = MemoryStore.create(cfg).calibrate(vecs).write(vecs, labs)
        q = vecs[:6] + 0.05 * jax.random.normal(jax.random.PRNGKey(1),
                                                (6, 24))
        eng = RetrievalEngine(cfg.search)
        mesh = jax.make_mesh((3,), ("data",))
        sstore = store.shard(mesh, ("data",))
        assert sstore.capacity == 102, sstore.capacity
        assert int((sstore.labels < 0).sum()) == 12  # 10 empty + 2 pad
        for mode in ("two_phase", "ideal"):
            req = SearchRequest(mode=mode, k=16)
            local = eng.search(store, q, req)
            with mesh:
                sh = eng.search(sstore, q, req)
            for key in ("votes", "dist", "indices", "labels"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(local, key)),
                    np.asarray(getattr(sh, key)), err_msg=f"{mode}/{key}")
        print("RAGGED-3WAY-OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "RAGGED-3WAY-OK" in proc.stdout
