"""MemoryStore: write-time MCAM layouts, ring wraparound, ragged shards,
and the unified engine.search contract (repro/engine/store.py, api.py).

The store's invariant: `values`, `proj`, `s_grid` and `labels` are written
TOGETHER (one programming operation), so at any point the store's search
artifacts are mutually consistent -- including after ring-buffer
wraparound -- and searches jit against the write-time constants instead of
re-running `layout_support` per call (asserted on compiled HLO below).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo_contracts as hc
from repro.core import avss as avss_lib
from repro.core.avss import SearchConfig
from repro.core.memory import MemoryConfig
from repro.engine import (MemoryStore, RetrievalEngine, SearchRequest,
                          SearchResult)
from repro.kernels import ops as kernel_ops

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(capacity=32, dim=16, cl=4):
    return MemoryConfig(capacity=capacity, dim=dim,
                        search=SearchConfig("mtmc", cl=cl, mode="avss",
                                            use_kernel="ref"))


def _assert_consistent(store):
    """proj and s_grid must equal the write-time functions of values."""
    enc = store.cfg.search.enc
    sl = store.cfg.search.mcam.string_len
    np.testing.assert_array_equal(
        np.asarray(store.proj),
        np.asarray(kernel_ops.support_projection(store.values, enc)))
    np.testing.assert_array_equal(
        np.asarray(store.s_grid),
        np.asarray(avss_lib.layout_support(store.values, enc, sl)
                   .astype(jnp.int8)))


def test_write_programs_all_layouts():
    cfg = _cfg()
    vecs = jax.random.normal(jax.random.PRNGKey(0), (20, cfg.dim))
    labs = jnp.arange(20, dtype=jnp.int32) % 5
    store = MemoryStore.create(cfg).calibrate(vecs).write(vecs, labs)
    _assert_consistent(store)
    assert int(store.size) == 20
    assert bool(store.valid[:20].all()) and not bool(store.valid[20:].any())


def test_ring_buffer_wraparound_consistency():
    """After writing > capacity vectors, every slot's values/proj/s_grid/
    labels stay mutually consistent, and search results are bit-identical
    to a store programmed directly with the surviving arrangement -- i.e.
    `size` (24 vs 16 here) plays no role in search, which is what makes
    the old `indices < size` validity check (vacuous once size > capacity)
    safe to drop in favour of the label mask."""
    cfg = _cfg(capacity=16, dim=8)
    key = jax.random.PRNGKey(3)
    vecs = jax.random.normal(key, (24, 8))
    labs = jnp.arange(24, dtype=jnp.int32)
    store = MemoryStore.create(cfg).calibrate(vecs)
    store = store.write(vecs[:16], labs[:16]).write(vecs[16:], labs[16:])
    _assert_consistent(store)
    # ring arrangement: slots 0..7 overwritten by vectors 16..23
    np.testing.assert_array_equal(np.asarray(store.labels),
                                  np.r_[np.arange(16, 24), np.arange(8, 16)])
    assert int(store.size) == 24

    # a store programmed with the surviving arrangement in one write
    surviving = jnp.concatenate([vecs[16:24], vecs[8:16]])
    slabs = jnp.concatenate([labs[16:24], labs[8:16]])
    fresh = MemoryStore.create(cfg).calibrate(vecs).write(surviving, slabs)
    np.testing.assert_array_equal(np.asarray(store.values),
                                  np.asarray(fresh.values))

    # values/proj/s_grid/labels being elementwise equal, search parity now
    # only needs to prove `size` (24 vs 16) leaks into no mode's result
    q = vecs[18:22] + 0.01
    eng = RetrievalEngine(cfg.search)
    for mode in ("two_phase", "ideal"):
        req = SearchRequest(mode=mode, k=8)
        f = jax.jit(lambda st, qq: eng.search(st, qq, req))
        a, b = f(store, q), f(fresh, q)
        for key_ in ("votes", "dist", "indices", "labels"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a, key_)), np.asarray(getattr(b, key_)),
                err_msg=f"{mode}/{key_}")


def test_search_result_pytree_roundtrips_jit():
    cfg = _cfg()
    vecs = jax.random.normal(jax.random.PRNGKey(1), (12, cfg.dim))
    labs = jnp.arange(12, dtype=jnp.int32) % 3
    store = MemoryStore.create(cfg).calibrate(vecs).write(vecs, labs)
    eng = RetrievalEngine(cfg.search)
    req = SearchRequest(mode="two_phase", k=4)
    res = jax.jit(lambda st, q: eng.search(st, q, req))(store, vecs[:3])
    assert isinstance(res, SearchResult)
    np.testing.assert_array_equal(np.asarray(res.predict()),
                                  np.asarray(labs[:3]))


def test_from_quantized_matches_raw_two_phase():
    """Unified API over a from_quantized store == raw-array two_phase,
    bit for bit (the old->new parity contract), on every backend."""
    cfg = SearchConfig("mtmc", cl=8, mode="avss", use_kernel="ref")
    sv = jax.random.randint(jax.random.PRNGKey(0), (40, 16), 0,
                            cfg.enc.levels)
    qv = jax.random.randint(jax.random.PRNGKey(1), (3, 16), 0, 4)
    store = MemoryStore.from_quantized(
        sv, jnp.arange(40, dtype=jnp.int32), cfg)
    for backend in ("ref", "mxu", "fused"):
        eng = RetrievalEngine(cfg, backend=backend)
        old = jax.jit(lambda s, q, e=eng: e.two_phase(q, s, k=8))(sv, qv)
        new = jax.jit(lambda st, q, e=eng: e.search(
            st, q, SearchRequest(mode="two_phase", k=8)))(store, qv)
        for key in ("votes", "dist", "indices"):
            np.testing.assert_array_equal(
                np.asarray(old[key]), np.asarray(getattr(new, key)),
                err_msg=f"{backend}/{key}")


def test_store_search_compiles_without_layout_support():
    """Acceptance: the store's grids are write-time constants -- compiling
    a store-based search emits NO layout_support ops (the named_scope tags
    them in HLO), while the raw-array path (read-time layout) does."""
    cfg = _cfg()
    vecs = jax.random.normal(jax.random.PRNGKey(0), (20, cfg.dim))
    labs = jnp.arange(20, dtype=jnp.int32)
    store = MemoryStore.create(cfg).calibrate(vecs).write(vecs, labs)
    eng = RetrievalEngine(cfg.search)
    req = SearchRequest(mode="two_phase", k=8)
    hlo_new = jax.jit(lambda st, q: eng.search(st, q, req).votes) \
        .lower(store, vecs[:2]).compile().as_text()
    hc.assert_no_layout_ops(hlo_new)
    # control: the raw-array two_phase still lays the store out under jit,
    # proving the scope tag is visible in this build's HLO text
    qv = store.quantize_queries(vecs[:2])
    hlo_old = jax.jit(lambda s, q: eng.two_phase(q, s, k=8)["votes"]) \
        .lower(store.values, qv).compile().as_text()
    hc.assert_layout_ops_present(hlo_old)


@pytest.mark.slow
def test_serve_decode_step_no_layout_under_jit():
    """The real `serve --retrieval` decode step (two-phase engine head)
    compiles with zero layout_support ops: the store programs its grids at
    write time and the jitted decode loop treats them as inputs."""
    from repro.configs import load_config
    from repro.launch import steps as steps_lib
    from repro.models import transformer as tfm
    from repro.models.sharding import Rules

    cfg = load_config("starcoder2-3b", smoke=True)
    rules = Rules(batch=(), fsdp=(), tensor=(), expert=())
    mem_cfg = MemoryConfig(capacity=64, dim=min(48, cfg.d_model),
                           search=SearchConfig("mtmc", cl=8, mode="avss",
                                               use_kernel="ref"))
    vecs = jax.random.normal(jax.random.PRNGKey(7), (32, mem_cfg.dim))
    toks = jax.random.randint(jax.random.PRNGKey(8), (32,), 0,
                              cfg.vocab_size)
    store = MemoryStore.create(mem_cfg).calibrate(vecs).write(vecs, toks)
    engine = RetrievalEngine(mem_cfg.search, backend="ref")
    step = steps_lib.make_serve_step_with_mcam(cfg, rules, mem_cfg,
                                               engine=engine, k=8)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    caches = tfm.init_cache(cfg, 2, 8)
    tok = jnp.zeros((2, 1), jnp.int32)
    hlo = jax.jit(step).lower(params, caches, {"tokens": tok},
                              jnp.int32(0), store).compile().as_text()
    hc.assert_no_layout_ops(hlo)


# ---------------------------------------------------------------------------
# Store lifecycle: calibration is enforced, not assumed.
# ---------------------------------------------------------------------------


def test_quantize_queries_requires_calibration():
    """Float queries on a never-calibrated store raise instead of silently
    quantizing against the uncalibrated default (lo=0, hi=1) range; integer
    (pre-quantized) queries always pass through."""
    cfg = _cfg()
    store = MemoryStore.create(cfg)
    with pytest.raises(ValueError, match="never-calibrated"):
        store.quantize_queries(jnp.zeros((2, cfg.dim)))
    qi = jnp.ones((2, cfg.dim), jnp.int32)
    np.testing.assert_array_equal(np.asarray(store.quantize_queries(qi)), 1)
    # from_quantized stores serve integer queries; float still raises
    fq = MemoryStore.from_quantized(
        jnp.zeros((4, cfg.dim), jnp.int32), jnp.arange(4, dtype=jnp.int32),
        cfg.search)
    with pytest.raises(ValueError, match="never-calibrated"):
        fq.quantize_queries(jnp.zeros((2, cfg.dim)))
    np.testing.assert_array_equal(np.asarray(fq.quantize_queries(qi)), 1)


def test_write_requires_calibration_and_recalibrate_raises():
    """calibrate() must run before the first write -- both directions are
    enforced: writing uncalibrated raises, and re-calibrating a store with
    programmed rows (which would silently invalidate their quantized
    words) raises too."""
    cfg = _cfg()
    vecs = jax.random.normal(jax.random.PRNGKey(0), (8, cfg.dim))
    labs = jnp.arange(8, dtype=jnp.int32)
    with pytest.raises(ValueError, match="calibrate"):
        MemoryStore.create(cfg).write(vecs, labs)
    store = MemoryStore.create(cfg).calibrate(vecs).write(vecs, labs)
    with pytest.raises(ValueError, match="programmed row"):
        store.calibrate(vecs)
    # an un-written calibrated store may re-calibrate freely
    MemoryStore.create(cfg).calibrate(vecs).calibrate(vecs * 2)


def test_empty_store_predicts_sentinel_every_mode_and_backend():
    """All-masked-candidates edge: an empty store (or one holding only
    ragged pad rows) yields predict() == -1 for every query in every
    mode/backend/sharding -- never an arbitrary class label (the sentinel
    documented on SearchResult)."""
    cfg = _cfg(capacity=12, dim=8)
    store = MemoryStore.create(cfg)
    q = jax.random.randint(jax.random.PRNGKey(0), (3, 8), 0, 4)
    for mode in ("full", "two_phase", "ideal"):
        for backend in ("ref", "mxu", "fused"):
            eng = RetrievalEngine(cfg.search, backend=backend)
            res = eng.search(store, q, SearchRequest(mode=mode, k=4))
            assert (np.asarray(res.predict()) == -1).all(), (mode, backend)
            assert np.isneginf(np.asarray(res.votes)).all(), (mode, backend)
    # sharded dispatch (two_phase + ideal go through shard_map)
    mesh = jax.make_mesh((1,), ("data",))
    sstore = store.shard(mesh, ("data",))
    eng = RetrievalEngine(cfg.search)
    for mode in ("two_phase", "ideal"):
        req = SearchRequest(mode=mode, k=4)
        res = jax.jit(lambda st, qq, r=req: eng.search(st, qq, r))(sstore, q)
        assert (np.asarray(res.predict()) == -1).all(), f"sharded/{mode}"


def test_request_backend_override_engine_is_cached():
    """SearchRequest.backend overrides resolve to ONE cached engine per
    (engine, backend): hot decode loops get the same object back every
    call instead of a rebuilt engine (and a cold jit closure)."""
    eng = RetrievalEngine(_cfg().search)
    assert eng.with_backend("auto") is eng
    a = eng.with_backend("fused")
    assert a is eng.with_backend("fused")
    assert a.backend == "fused" and a.cfg is eng.cfg
    # the override engine caches too, and distinct backends stay distinct
    assert eng.with_backend("mxu") is not a
    assert a.with_backend("fused") is a


# ---------------------------------------------------------------------------
# Streaming (shard-local) writes.
# ---------------------------------------------------------------------------


def test_single_shard_write_dispatches_to_scatter():
    """A 1-shard mesh gives the shard_map write-through nothing to
    parallelise: there is no collective to avoid, and its per-row ring
    inversion runs 7.7x slower than the scatter (bench_engine_sharded
    write rows). `write` therefore routes single-shard stores through the
    plain scatter path -- bit-identical to the write-through (invoked
    directly here as the parity control), sharding metadata preserved.
    The no-scatter/no-collective HLO contract lives with the 8-device
    test below, where the write-through actually engages."""
    cfg = _cfg(capacity=16, dim=8)
    vecs = jax.random.normal(jax.random.PRNGKey(0), (22, 8))
    labs = jnp.arange(22, dtype=jnp.int32)
    base = MemoryStore.create(cfg).calibrate(vecs)
    mesh = jax.make_mesh((1,), ("data",))
    sstore = base.shard(mesh, ("data",))
    assert sstore.n_shards == 1 and base.n_shards == 1
    f = jax.jit(lambda st, v, l: st.write(v, l))
    written = f(f(sstore, vecs[:12], labs[:12]), vecs[12:], labs[12:])
    assert int(written.size) == 22  # wrapped: slots 0..5 overwritten

    # parity control: the write-through path, invoked directly
    from repro.engine.store import _quantize

    def stream_write(st, v, l):
        vq = _quantize(v, st.cfg.search.enc.levels, st.lo, st.hi)
        return st._program_streamed(vq, l, v.shape[0])
    g = jax.jit(stream_write)
    streamed = g(g(sstore, vecs[:12], labs[:12]), vecs[12:], labs[12:])
    for key in ("values", "proj", "proj_packed", "s_grid", "labels",
                "size"):
        np.testing.assert_array_equal(
            np.asarray(getattr(streamed, key)),
            np.asarray(getattr(written, key)), err_msg=key)
    assert written.mesh is mesh and written.axes == ("data",)
    # the dispatched write lowers to the scatter (expanded on CPU to
    # dynamic-update-slice), proving the fast path actually engaged
    hlo = jax.jit(lambda st, v, l: st.write(v, l)) \
        .lower(sstore, vecs[:12], labs[:12]).compile().as_text()
    hc.assert_scatter_write(hlo)
    # ...and matches the scatter path on the unsharded store exactly
    scattered = base.write(vecs[:12], labs[:12]).write(vecs[12:], labs[12:])
    for key in ("values", "proj", "proj_packed", "s_grid", "labels",
                "size"):
        np.testing.assert_array_equal(
            np.asarray(getattr(scattered, key)),
            np.asarray(getattr(written, key)), err_msg=key)


@pytest.mark.slow
def test_streaming_write_8dev_no_collectives_ragged_wraparound():
    """Acceptance (ISSUE 3 tentpole): on a forced 8-device mesh, the
    sharded write-through (a) compiles to HLO with NO cross-device
    collectives and no scatter, (b) is bit-identical to the scatter path
    on a RAGGED-padded store with ring wraparound crossing shard
    boundaries, and (c) searches of the streamed store match the
    unsharded reference bit-for-bit. Also covers the shard->shard(other
    mesh) idempotency fix and the fully-pad-row predict() sentinel."""
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.analysis import hlo_contracts as hc
        from repro.core.avss import SearchConfig
        from repro.core.memory import MemoryConfig
        from repro.engine import MemoryStore, RetrievalEngine, SearchRequest
        from repro.engine.store import _quantize

        cfg = MemoryConfig(capacity=100, dim=24,
                           search=SearchConfig("mtmc", cl=8, mode="avss",
                                               use_kernel="ref"))
        vecs = jax.random.normal(jax.random.PRNGKey(0), (130, 24))
        labs = jnp.arange(130, dtype=jnp.int32) % 9
        base = MemoryStore.create(cfg).calibrate(vecs)
        mesh8 = jax.make_mesh((8,), ("data",))

        # (a) compiled write HLO: no collectives, no scatter of any form
        sstore = base.shard(mesh8, ("data",))
        assert sstore.capacity == 104, sstore.capacity  # ragged pad
        write = jax.jit(lambda st, v, l: st.write(v, l))
        hlo = write.lower(sstore, vecs[:60], labs[:60]).compile().as_text()
        hc.assert_no_collectives(hlo)
        hc.assert_no_scatter_any_spelling(hlo)
        # control: the scatter path lowers to the expanded scatter
        def old_write(st, v, l):
            vq = _quantize(v, st.cfg.search.enc.levels, st.lo, st.hi)
            start = st.size % st.cfg.capacity
            idx = (start + jnp.arange(v.shape[0])) % st.cfg.capacity
            return st._program(idx, vq, l, v.shape[0])
        hlo_old = jax.jit(old_write).lower(
            sstore, vecs[:60], labs[:60]).compile().as_text()
        hc.assert_scatter_write(hlo_old)

        # (b) bit-parity: ragged pads + ring wraparound across shards.
        # 90 rows, then 40 more -> wraps 30 past capacity back to rows
        # 0..29, crossing the 13-row shard boundaries of the padded store.
        streamed = write(write(sstore, vecs[:90], labs[:90]),
                         vecs[90:], labs[90:])
        scattered = base.write(vecs[:90], labs[:90]).write(
            vecs[90:], labs[90:]).shard(mesh8, ("data",))
        for key in ("values", "proj", "s_grid", "labels", "size"):
            np.testing.assert_array_equal(
                np.asarray(getattr(scattered, key)),
                np.asarray(getattr(streamed, key)), err_msg=key)

        # (c) search parity: streamed sharded store == unsharded reference
        unsharded = base.write(vecs[:90], labs[:90]).write(vecs[90:],
                                                           labs[90:])
        q = vecs[95:101] + 0.02
        eng = RetrievalEngine(cfg.search)
        for mode in ("two_phase", "ideal"):
            req = SearchRequest(mode=mode, k=16)
            ref = eng.search(unsharded, q, req)
            with mesh8:
                got = jax.jit(lambda st, qq, r=req: eng.search(
                    st, qq, r))(streamed, q)
            for key in ("votes", "dist", "indices", "labels"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(ref, key)),
                    np.asarray(getattr(got, key)), err_msg=f"{mode}/{key}")

        # shard -> shard(other mesh): pads must not accumulate, and the
        # result must equal sharding the logical store directly
        mesh3 = Mesh(np.asarray(jax.devices()[:3]), ("data",))
        written = unsharded
        via3 = written.shard(mesh3, ("data",))
        assert via3.capacity == 102, via3.capacity     # 100 -> pad 2
        re8 = via3.shard(mesh8, ("data",))
        direct8 = written.shard(mesh8, ("data",))
        assert re8.capacity == 104, re8.capacity       # NOT pad-of-pad
        for key in ("values", "proj", "s_grid", "labels", "size"):
            np.testing.assert_array_equal(
                np.asarray(getattr(direct8, key)),
                np.asarray(getattr(re8, key)), err_msg=f"reshard/{key}")

        # fully-pad/empty sharded store: predict() == -1 everywhere
        empty = MemoryStore.create(cfg).shard(mesh8, ("data",))
        qi = jax.random.randint(jax.random.PRNGKey(3), (4, 24), 0, 4)
        for mode in ("two_phase", "ideal"):
            with mesh8:
                res = jax.jit(lambda st, qq, r=SearchRequest(mode=mode, k=8):
                              eng.search(st, qq, r))(empty, qi)
            assert (np.asarray(res.predict()) == -1).all(), mode
        print("STREAMING-WRITE-OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "STREAMING-WRITE-OK" in proc.stdout


@pytest.mark.slow
def test_ragged_3way_split_capacity_100():
    """ROADMAP open item: capacity need not divide the shard count.
    A capacity-100 store sharded 3 ways pads to 102 rows with label -1
    rows that the integer-exact penalty ranks last -- votes/dist/indices/
    labels bit-identical to the unsharded search."""
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.avss import SearchConfig
        from repro.core.memory import MemoryConfig
        from repro.engine import MemoryStore, RetrievalEngine, SearchRequest

        cfg = MemoryConfig(capacity=100, dim=24,
                           search=SearchConfig("mtmc", cl=8, mode="avss",
                                               use_kernel="ref"))
        vecs = jax.random.normal(jax.random.PRNGKey(0), (90, 24))
        labs = jnp.arange(90, dtype=jnp.int32) % 9
        store = MemoryStore.create(cfg).calibrate(vecs).write(vecs, labs)
        q = vecs[:6] + 0.05 * jax.random.normal(jax.random.PRNGKey(1),
                                                (6, 24))
        eng = RetrievalEngine(cfg.search)
        mesh = jax.make_mesh((3,), ("data",))
        sstore = store.shard(mesh, ("data",))
        assert sstore.capacity == 102, sstore.capacity
        assert int((sstore.labels < 0).sum()) == 12  # 10 empty + 2 pad
        for mode in ("two_phase", "ideal"):
            req = SearchRequest(mode=mode, k=16)
            local = eng.search(store, q, req)
            with mesh:
                sh = eng.search(sstore, q, req)
            for key in ("votes", "dist", "indices", "labels"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(local, key)),
                    np.asarray(getattr(sh, key)), err_msg=f"{mode}/{key}")
        print("RAGGED-3WAY-OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "RAGGED-3WAY-OK" in proc.stdout
