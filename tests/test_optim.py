"""Optimizers: convergence on quadratics, 8-bit state fidelity, schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adafactor, adamw, adamw8bit, clip_by_global_norm,
                         global_norm, sgd, warmup_cosine)
from repro.optim.optimizers import _dq8, _q8


def _minimize(opt, steps=200, dim=(8, 6)):
    target = jnp.arange(np.prod(dim), dtype=jnp.float32).reshape(dim) / 10
    params = {"w": jnp.zeros(dim)}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        grads = jax.tree_util.tree_map(lambda p: p - target, params)
        loss = jnp.sum((params["w"] - target) ** 2)
        updates, state = opt.update(grads, state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
        return params, state, loss

    for _ in range(steps):
        params, state, loss = step(params, state)
    return float(loss)


@pytest.mark.parametrize("make,steps", [
    (lambda: adamw(0.05, weight_decay=0.0), 200),
    (lambda: adamw(0.05, weight_decay=0.0, state_dtype=jnp.bfloat16), 200),
    (lambda: adamw8bit(0.05, weight_decay=0.0), 200),
    # adafactor's sign-like steps need a decaying schedule to settle
    (lambda: adafactor(warmup_cosine(0.5, 10, 400), weight_decay=0.0), 400),
    (lambda: sgd(0.05), 200),
])
def test_optimizers_converge(make, steps):
    assert _minimize(make(), steps=steps) < 1e-2


def test_q8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 5
    q, s = _q8(x)
    back = _dq8(q, s, x.shape)
    err = np.abs(np.asarray(back - x))
    assert err.max() <= np.abs(np.asarray(x)).max() / 127.0 + 1e-6


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((5,), -4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0
    # under the limit: unchanged
    g2 = {"a": jnp.full((4,), 0.1)}
    c2, _ = clip_by_global_norm(g2, 10.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), 0.1, rtol=1e-6)


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, warmup=10, total=100)
    lrs = [float(s(jnp.int32(i))) for i in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6      # warmup rises
    assert lrs[12] > lrs[50] > lrs[99]        # cosine decays
    assert lrs[99] >= 0.1 * 0.99              # floor


def test_adamw8bit_tracks_adamw():
    """8-bit moments follow fp32 moments closely on a smooth problem."""
    l32 = _minimize(adamw(0.02, weight_decay=0.0), steps=300)
    l8 = _minimize(adamw8bit(0.02, weight_decay=0.0), steps=300)
    assert l8 < max(10 * max(l32, 1e-6), 1e-2)
