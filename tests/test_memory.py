"""MCAM external memory module: write/search/predict + distributed search."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import memory as mem
from repro.core.avss import SearchConfig
from repro.core.memory import MemoryConfig

# Legacy-API suite: the deprecation shims legitimately fire here, so the
# suite-wide promotion to errors (tests/conftest.py) is scoped back.
pytestmark = pytest.mark.filterwarnings(
    "default:repro\\.core\\.memory:DeprecationWarning")


def _toy_memory(n_classes=6, per_class=8, dim=24, key=0):
    cfg = MemoryConfig(capacity=128, dim=dim,
                       search=SearchConfig(encoding="mtmc", cl=8,
                                           mode="avss", use_kernel="ref"))
    centers = jax.random.normal(jax.random.PRNGKey(key), (n_classes, dim)) * 2
    ks = jax.random.split(jax.random.PRNGKey(key + 1), n_classes)
    vecs, labels = [], []
    for c in range(n_classes):
        vecs.append(centers[c] + 0.2 * jax.random.normal(ks[c],
                                                         (per_class, dim)))
        labels += [c] * per_class
    vecs = jnp.concatenate(vecs)
    labels = jnp.asarray(labels, jnp.int32)
    state = mem.init_memory(cfg)
    state = mem.calibrate(state, vecs, cfg)
    state = mem.write(state, vecs, labels, cfg)
    return cfg, state, centers


@pytest.mark.slow
def test_write_and_1nn_predict():
    cfg, state, centers = _toy_memory()
    queries = centers + 0.1 * jax.random.normal(jax.random.PRNGKey(9),
                                                centers.shape)
    res = mem.search(state, queries, cfg)
    pred = mem.predict(res)
    np.testing.assert_array_equal(np.asarray(pred), np.arange(6))


@pytest.mark.slow
def test_two_phase_predict_matches():
    cfg, state, centers = _toy_memory()
    queries = centers + 0.1 * jax.random.normal(jax.random.PRNGKey(9),
                                                centers.shape)
    res = mem.search(state, queries, cfg, two_phase=True, k=16)
    pred = mem.predict(res)
    np.testing.assert_array_equal(np.asarray(pred), np.arange(6))


@pytest.mark.slow
def test_unwritten_slots_masked():
    cfg, state, _ = _toy_memory(per_class=2)  # 12 of 128 slots used
    q = jax.random.normal(jax.random.PRNGKey(3), (4, cfg.dim))
    res = mem.search(state, q, cfg)
    votes = np.asarray(res["votes"])
    assert np.isneginf(votes[:, int(state["size"]):]).all()


def test_ring_buffer_overwrite():
    cfg = MemoryConfig(capacity=16, dim=8,
                       search=SearchConfig(encoding="mtmc", cl=4,
                                           mode="avss", use_kernel="ref"))
    state = mem.init_memory(cfg)
    v1 = jnp.ones((16, 8))
    state = mem.calibrate(state, v1, cfg)
    state = mem.write(state, v1, jnp.zeros((16,), jnp.int32), cfg)
    v2 = -jnp.ones((8, 8))
    state = mem.write(state, v2, jnp.ones((8,), jnp.int32), cfg)
    labels = np.asarray(state["labels"])
    assert (labels[:8] == 1).all() and (labels[8:] == 0).all()


@pytest.mark.slow
def test_distributed_search_matches_local():
    cfg, state, centers = _toy_memory(dim=24)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sstate = mem.shard_state(state, mesh, ("data", "model"))
    q = centers + 0.05 * jax.random.normal(jax.random.PRNGKey(5),
                                           centers.shape)
    with mesh:
        res = mem.distributed_search(sstate, q, cfg, mesh, k=8)
    # top-1 label should match the local exact ideal-distance search
    pred = np.asarray(res["labels"])[:, 0]
    np.testing.assert_array_equal(pred, np.arange(6))
