"""Checkpointing: roundtrip, atomicity, async, GC, elastic restore."""

import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"params": {"w": jax.random.normal(k, (16, 8)),
                       "b": jnp.arange(8, dtype=jnp.float32)},
            "opt": {"m": jnp.ones((16, 8), jnp.bfloat16)},
            "step": jnp.int32(7)}


def _assert_tree_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        assert la.dtype == lb.dtype


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t)
    out = restore(str(tmp_path), jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t))
    _assert_tree_equal(t, out)


def test_async_and_latest(tmp_path):
    t = _tree()
    th = save(str(tmp_path), 3, t, blocking=False)
    assert th is None or isinstance(th, threading.Thread)
    if th:
        th.join()
    save(str(tmp_path), 9, t)
    assert latest_step(str(tmp_path)) == 9


def test_atomicity_no_partial_dirs(tmp_path):
    save(str(tmp_path), 1, _tree())
    entries = os.listdir(tmp_path)
    assert all(not e.startswith(".tmp") for e in entries)
    # a directory without manifest is ignored
    os.makedirs(tmp_path / "step_0000000099")
    assert latest_step(str(tmp_path)) == 1


def test_gc_keeps_newest(tmp_path):
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, _tree(), keep=2)
    from repro.checkpoint.ckpt import all_steps
    assert all_steps(str(tmp_path)) == [4, 5]


def test_elastic_restore_onto_sharding(tmp_path):
    """Save unsharded, restore onto a mesh sharding (elastic restart)."""
    t = _tree()
    save(str(tmp_path), 5, t)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    shardings = jax.tree_util.tree_map(
        lambda a: NamedSharding(mesh, P()), t)
    out = restore(str(tmp_path), jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t),
        shardings=shardings)
    _assert_tree_equal(t, out)
    assert out["params"]["w"].sharding == NamedSharding(mesh, P())


def test_manager_flow(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=2, keep=2)
    t = _tree()
    for step in range(1, 6):
        mgr.maybe_save(step, t)
    mgr.wait()
    assert mgr.latest_step() == 4  # steps 2 and 4 saved
    out = mgr.restore(jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t))
    _assert_tree_equal(t, out)


def test_missing_leaf_raises(tmp_path):
    save(str(tmp_path), 1, {"a": jnp.ones((3,))})
    with pytest.raises(KeyError):
        restore(str(tmp_path), {"b": jax.ShapeDtypeStruct((3,), jnp.float32)})


# ---------------------------------------------------------------------------
# MemoryStore persistence (ISSUE 5 satellite): a trained-and-written store
# round-trips through checkpoint/ckpt.py bit-identically, so a separate
# serving process can restore and search it.
# ---------------------------------------------------------------------------


def _programmed_store():
    from repro.core.avss import SearchConfig
    from repro.core.memory import MemoryConfig
    from repro.engine import MemoryStore
    cfg = MemoryConfig(capacity=12, dim=6,
                       search=SearchConfig("mtmc", cl=4, mode="avss",
                                           use_kernel="ref"))
    vecs = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(0), (9, 6)))
    labels = jnp.arange(9, dtype=jnp.int32) % 3
    store = MemoryStore.create(cfg).calibrate(vecs).write(vecs, labels)
    return cfg, vecs, store


def test_memory_store_save_restore_bit_parity(tmp_path):
    """Every persisted field (values/labels/proj/s_grid/lo/hi/size) round-
    trips exactly, the restored store is marked calibrated, and searches on
    it are bit-identical to the writer's store."""
    from repro.engine import MemoryStore, RetrievalEngine, SearchRequest
    cfg, vecs, store = _programmed_store()
    store.save(str(tmp_path), step=5)
    restored = MemoryStore.restore(str(tmp_path), cfg)
    for field in ("values", "proj", "s_grid", "labels", "size", "lo", "hi"):
        a, b = getattr(store, field), getattr(restored, field)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=field)
        assert a.dtype == b.dtype, field
    assert restored.calibrated and int(restored.size) == 9
    # a float query exercises the restored calibration range end-to-end
    eng = RetrievalEngine(cfg.search)
    req = SearchRequest(mode="two_phase", k=6)
    want = eng.search(store, vecs[:4], req)
    got = eng.search(restored, vecs[:4], req)
    for field in ("votes", "dist", "indices", "labels"):
        np.testing.assert_array_equal(np.asarray(getattr(want, field)),
                                      np.asarray(getattr(got, field)),
                                      err_msg=field)


def test_memory_store_restore_is_calibrated_and_writable(tmp_path):
    """A restored store IS calibrated (the persisted range is the
    calibration): writing more supports to it works without re-calibrating,
    and the ring position continues from the persisted size."""
    from repro.engine import MemoryStore
    cfg, vecs, store = _programmed_store()
    store.save(str(tmp_path))
    restored = MemoryStore.restore(str(tmp_path), cfg)
    more = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (2, 6)))
    grown = restored.write(more, jnp.array([5, 6], jnp.int32))
    assert int(grown.size) == 11
    np.testing.assert_array_equal(np.asarray(grown.labels[9:11]), [5, 6])
