"""Deliverable (f): per-arch smoke tests -- reduced same-family config, one
forward + one train step on CPU, asserting output shapes + no NaNs."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, load_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.launch import steps as steps_lib
from repro.models import transformer as tfm
from repro.models.sharding import Rules

LM_ARCHS = ARCHS[:10]


def _batch(cfg, B, S, key):
    if cfg.input_mode == "tokens":
        b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    else:
        b = {"embeddings": jax.random.normal(key, (B, S, cfg.d_model))}
        if cfg.rope_type == "mrope":
            b["positions3"] = jnp.broadcast_to(
                jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32)
    b["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return b


@pytest.mark.slow
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward(arch):
    cfg = load_config(arch, smoke=True)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))
    logits, aux = tfm.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_smoke_forward_canary():
    """Fast-tier canary: one reduced arch forward on every push; the full
    arch x {forward, train, decode} matrix is @slow (weekly/full tier)."""
    test_smoke_forward("starcoder2-3b")


@pytest.mark.slow
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_train_step(arch):
    cfg = load_config(arch, smoke=True)
    shape = ShapeConfig("smoke", 16, 4, "train", microbatch=2)
    tc = TrainConfig(learning_rate=1e-3)
    rules = Rules(batch=(), fsdp=(), tensor=(), expert=())
    step, optimizer = steps_lib.make_train_step(cfg, tc, rules)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    opt_state = optimizer.init(params)
    b = _batch(cfg, 4, 16, jax.random.PRNGKey(1))
    b = {k: v.reshape((2, 2) + v.shape[1:]) for k, v in b.items()}
    params2, opt_state, metrics = jax.jit(step)(params, opt_state, b)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["applied"]) == 1.0
    # params actually changed
    d = jax.tree_util.tree_reduce(
        lambda acc, t: acc + float(jnp.sum(jnp.abs(t[0] - t[1]))),
        jax.tree_util.tree_map(lambda a, b_: (a.astype(jnp.float32),
                                              b_.astype(jnp.float32)),
                               params, params2), 0.0)
    assert d > 0.0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3-405b", "deepseek-v3-671b",
                                  "xlstm-350m", "hymba-1.5b"])
def test_smoke_decode_step(arch):
    cfg = load_config(arch, smoke=True)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    B = 2
    caches = tfm.init_cache(cfg, B, 32)
    b = _batch(cfg, B, 1, jax.random.PRNGKey(1))
    b.pop("labels")
    logits, caches = tfm.decode_step(params, cfg, b, caches, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment block."""
    spec = {
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    }
    for arch, (L, D, H, KV, F, V) in spec.items():
        cfg = load_config(arch)
        assert cfg.n_layers == L and cfg.d_model == D, arch
        assert cfg.n_heads == H and cfg.n_kv_heads == KV, arch
        assert cfg.d_ff == F and cfg.vocab_size == V, arch
    assert load_config("deepseek-v3-671b").mla is not None
    assert load_config("deepseek-v3-671b").moe.n_routed == 256
    assert load_config("deepseek-moe-16b").moe.top_k == 6
    assert load_config("hymba-1.5b").ssm.d_state == 16


@pytest.mark.slow
def test_param_counts_in_range():
    """Sanity: total parameter counts are near the advertised sizes."""
    import numpy as np
    expect = {"llama3-405b": 405e9, "deepseek-v3-671b": 671e9,
              "qwen1.5-110b": 111e9, "command-r-plus-104b": 104e9,
              "starcoder2-3b": 3e9, "deepseek-moe-16b": 16.4e9,
              "qwen2-vl-7b": 7.6e9, "musicgen-medium": 1.5e9,
              "hymba-1.5b": 1.5e9, "xlstm-350m": 0.35e9}
    for arch, target in expect.items():
        cfg = load_config(arch)
        aps = tfm.abstract_params(cfg)
        n = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(aps))
        assert 0.7 * target < n < 1.45 * target, (arch, n / 1e9)
