"""Fast-tier `deprecations` check (CI): the legacy `memory.search` /
`memory.distributed_search` shims emit a DeprecationWarning EXACTLY once
per process per function, and return bit-identical results to the unified
`RetrievalEngine.search(store, queries, SearchRequest)` API.

The shim calls are jitted (eager shard_map retraces per op and costs ~10s
per call on this suite's CI budget); the warning fires at TRACE time, so
the repeat call uses a different query batch size to force a retrace --
an unguarded shim would warn again there.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import memory as mem
from repro.core.avss import SearchConfig
from repro.core.memory import MemoryConfig
from repro.engine import MemoryStore, RetrievalEngine, SearchRequest


@pytest.fixture()
def toy():
    cfg = MemoryConfig(capacity=16, dim=8,
                       search=SearchConfig("mtmc", cl=4, mode="avss",
                                           use_kernel="ref"))
    vecs = jax.random.normal(jax.random.PRNGKey(0), (12, cfg.dim))
    labs = jnp.arange(12, dtype=jnp.int32) % 4
    state = mem.init_memory(cfg)
    state = mem.calibrate(state, vecs, cfg)
    state = mem.write(state, vecs, labs, cfg)
    q = vecs[:3] + 0.02
    return cfg, state, q


def _deprecations(records):
    return [w for w in records if issubclass(w.category, DeprecationWarning)
            and "repro.core.memory" in str(w.message)]


def test_search_shim_warns_once_and_is_bit_identical(toy):
    cfg, state, q = toy
    mem._WARNED.discard("search")
    f_full = jax.jit(lambda s, qq: mem.search(s, qq, cfg))
    f_tp = jax.jit(lambda s, qq: mem.search(s, qq, cfg, two_phase=True,
                                            k=4))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        old_full = f_full(state, q)
        old_tp = f_tp(state, q)
        f_tp(state, q[:2])              # retrace: must NOT warn again
    assert len(_deprecations(rec)) == 1, [str(w.message) for w in rec]

    eng = RetrievalEngine(cfg.search)
    store = MemoryStore.from_state(state, cfg)
    new_full = jax.jit(lambda st, qq: eng.search(
        st, qq, SearchRequest(mode="full")))(store, q)
    new_tp = jax.jit(lambda st, qq: eng.search(
        st, qq, SearchRequest(mode="two_phase", k=4)))(store, q)
    for key in ("votes", "dist", "labels"):
        np.testing.assert_array_equal(np.asarray(old_full[key]),
                                      np.asarray(getattr(new_full, key)),
                                      err_msg=f"full/{key}")
    for key in ("votes", "dist", "indices", "labels"):
        np.testing.assert_array_equal(np.asarray(old_tp[key]),
                                      np.asarray(getattr(new_tp, key)),
                                      err_msg=f"two_phase/{key}")
    # predict agrees across the result types too
    np.testing.assert_array_equal(np.asarray(mem.predict(old_tp)),
                                  np.asarray(new_tp.predict()))


def test_distributed_shim_warns_once_and_is_bit_identical(toy):
    cfg, state, q = toy
    mesh = jax.make_mesh((1,), ("data",))
    mem._WARNED.discard("distributed_search")
    f_old = jax.jit(lambda s, qq: mem.distributed_search(
        s, qq, cfg, mesh, axes=("data",), k=4))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        with mesh:
            old = f_old(state, q)
            f_old(state, q[:2])         # retrace: must NOT warn again
    assert len(_deprecations(rec)) == 1, [str(w.message) for w in rec]

    eng = RetrievalEngine(cfg.search)
    sstore = MemoryStore.from_state(state, cfg).shard(mesh, ("data",))
    with mesh:
        new = jax.jit(lambda st, qq: eng.search(
            st, qq, SearchRequest(mode="two_phase", k=4)))(sstore, q)
    for key in ("votes", "dist", "indices", "labels"):
        np.testing.assert_array_equal(np.asarray(old[key]),
                                      np.asarray(getattr(new, key)),
                                      err_msg=key)
