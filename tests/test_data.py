"""Data pipelines: determinism, resumability, episode structure."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from repro.data.fsl import CUBLike, EpisodeSampler, OmniglotLike, pretrain_batch
from repro.data.lm import LMDataConfig, SyntheticLM, embedding_batch_for_step


def test_lm_determinism_and_resume():
    cfg = LMDataConfig(seq_len=32, global_batch=4, vocab_size=512)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1 = d1.batch_for_step(17)
    b2 = d2.batch_for_step(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_lm_host_sharding_partitions_batch():
    cfg = LMDataConfig(seq_len=16, global_batch=8, vocab_size=128)
    d = SyntheticLM(cfg)
    full = d.batch_for_step(3)["tokens"]
    h0 = d.batch_for_step(3, host_index=0, host_count=2)["tokens"]
    h1 = d.batch_for_step(3, host_index=1, host_count=2)["tokens"]
    np.testing.assert_array_equal(np.concatenate([h0, h1]), full)


def test_lm_motifs_make_structure():
    cfg = LMDataConfig(seq_len=128, global_batch=2, vocab_size=1024)
    toks = SyntheticLM(cfg).batch_for_step(0)["tokens"]
    # motifs repeat => some 8-gram appears more than once per row
    row = toks[0]
    grams = {}
    for i in range(len(row) - 8):
        grams[tuple(row[i:i + 8])] = grams.get(tuple(row[i:i + 8]), 0) + 1
    assert max(grams.values()) >= 2


def test_embedding_batch_mrope():
    b = embedding_batch_for_step(0, 2, 16, 32, 100, mrope=True)
    assert b["embeddings"].shape == (2, 16, 32)
    assert b["positions3"].shape == (2, 16, 3)


@pytest.mark.parametrize("ds_cls,ch", [(OmniglotLike, 1), (CUBLike, 3)])
def test_class_images_deterministic(ds_cls, ch):
    ds = ds_cls(n_classes=10, image_size=20, seed=3)
    a = ds.class_images(2, 3, rng_seed=5)
    b = ds.class_images(2, 3, rng_seed=5)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (3, 20, 20, ch)
    assert a.min() >= 0.0 and a.max() <= 1.0
    # different class => different images
    c = ds.class_images(3, 3, rng_seed=5)
    assert not np.allclose(a, c)


def test_class_structure_separable():
    """Within-class distances < between-class distances (learnable)."""
    ds = OmniglotLike(n_classes=8, image_size=20, seed=0)
    imgs = [ds.class_images(c, 4, rng_seed=1).reshape(4, -1)
            for c in range(8)]
    within, between = [], []
    for c in range(8):
        for i in range(4):
            for j in range(i + 1, 4):
                within.append(np.abs(imgs[c][i] - imgs[c][j]).mean())
        for c2 in range(c + 1, 8):
            between.append(np.abs(imgs[c][0] - imgs[c2][0]).mean())
    assert np.mean(within) < np.mean(between)


def test_episode_sampler_invariants():
    ds = OmniglotLike(n_classes=30, image_size=16, seed=0)
    samp = EpisodeSampler(ds, class_ids=np.arange(30), n_way=5, k_shot=3,
                          n_query=2, seed=1)
    ep = samp.episode(0)
    assert ep.support_images.shape[0] == 15
    assert ep.query_images.shape[0] == 10
    assert set(np.asarray(ep.support_labels)) == set(range(5))
    assert len(np.unique(ep.class_ids)) == 5
    # deterministic
    ep2 = samp.episode(0)
    np.testing.assert_array_equal(ep.support_images, ep2.support_images)
    # different episodes differ
    ep3 = samp.episode(1)
    assert not np.array_equal(ep.class_ids, ep3.class_ids) or \
        not np.allclose(ep.support_images, ep3.support_images)


def test_pretrain_batch():
    ds = OmniglotLike(n_classes=12, image_size=16, seed=0)
    b = pretrain_batch(ds, np.arange(12), batch=6, step=0)
    assert b["image"].shape == (6, 16, 16, 1)
    assert b["label"].min() >= 0 and b["label"].max() < 12
