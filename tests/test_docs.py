"""Documentation stays executable (fast tier, every CI push).

Two checks keep the new docs surface from rotting:

* doctests on the public API (`engine/api.py`, `engine/store.py`,
  `engine/engine.py`, `kernels/shortlist.py`, since ISSUE 5 the trainer
  surface `core/hat.py` + `launch/steps.py`, since ISSUE 9 the
  multi-tenant surface `engine/tenant.py`, and since ISSUE 10 the memory
  hierarchy `engine/router.py` + `engine/pager.py`) -- the same modules
  CI also runs through `pytest --doctest-modules`;
* extract-and-run over every ```python block in README.md and docs/*.md
  (blocks in one file share a namespace, so a later block may build on an
  earlier one; shell examples use ```bash fences and are not executed).
"""

import doctest
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent

PUBLIC_MODULES = ("repro.engine.api", "repro.engine.store",
                  "repro.engine.engine", "repro.engine.tenant",
                  "repro.engine.router", "repro.engine.pager",
                  "repro.kernels.shortlist", "repro.core.hat",
                  "repro.launch.steps")


@pytest.mark.parametrize("modname", PUBLIC_MODULES)
def test_public_api_doctests(modname):
    """Every docstring example on the public surface runs and passes --
    and each of these modules is required to HAVE at least one (the
    docstring-pass contract of ISSUE 4)."""
    mod = __import__(modname, fromlist=["_"])
    res = doctest.testmod(mod, verbose=False)
    assert res.attempted > 0, f"{modname} lost its docstring examples"
    assert res.failed == 0, f"{modname}: {res.failed} doctest(s) failed"


DOC_FILES = ("README.md", "docs/architecture.md", "docs/migration.md")


@pytest.mark.parametrize("relpath", DOC_FILES)
def test_doc_exists_and_python_blocks_execute(relpath):
    """The documented code is real code: each ```python block compiles and
    executes (sequentially, sharing one namespace per file)."""
    path = ROOT / relpath
    assert path.exists(), f"{relpath} is part of the documented surface"
    blocks = re.findall(r"```python\n(.*?)```", path.read_text(), re.DOTALL)
    if relpath != "docs/architecture.md":   # architecture may be prose-only
        assert blocks, f"{relpath} has no ```python blocks"
    ns = {}
    for i, block in enumerate(blocks):
        code = compile(block, f"{relpath}[python block {i}]", "exec")
        exec(code, ns)                      # noqa: S102 -- the whole point
