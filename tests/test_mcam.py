"""MCAM behavioural model: bottleneck ordering, monotonicity, noise."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mcam
from repro.core.mcam import MCAMConfig


def test_current_monotone_in_total_mismatch():
    cfg = MCAMConfig()
    # strings with s cells at mismatch-1, rest 0
    cur = []
    for s in range(0, 24):
        cells = jnp.array([1.0] * s + [0.0] * (24 - s))
        cur.append(float(mcam.string_current(cells[None], cfg)[0]))
    assert all(a > b for a, b in zip(cur, cur[1:]))


def test_bottleneck_ordering_fig2c():
    """Same total mismatch (6): six 1s > three 2s > two 3s (Fig. 2(c))."""
    cfg = MCAMConfig()
    mk = lambda lv, n: jnp.array([float(lv)] * n + [0.0] * (24 - n))[None]
    i1 = float(mcam.string_current(mk(1, 6), cfg)[0])
    i2 = float(mcam.string_current(mk(2, 3), cfg)[0])
    i3 = float(mcam.string_current(mk(3, 2), cfg)[0])
    assert i1 > i2 > i3


def test_single_mismatch3_dominates():
    """One mismatch-3 cell sinks the string below many mismatch-1 cells."""
    cfg = MCAMConfig()
    many_small = jnp.array([1.0] * 20 + [0.0] * 4)[None]
    one_big = jnp.array([3.0] + [0.0] * 23)[None]
    i_small = float(mcam.string_current(many_small, cfg)[0])
    i_big = float(mcam.string_current(one_big, cfg)[0])
    assert i_big < i_small


def test_thresholds_sorted_in_range():
    cfg = MCAMConfig(n_thresholds=8)
    th = cfg.thresholds()
    assert len(th) == 8
    assert (np.diff(th) > 0).all()
    assert th.max() < 1.0 and th.min() > 0.0


def test_sa_votes_monotone():
    cfg = MCAMConfig()
    th = jnp.asarray(cfg.thresholds())
    cur = jnp.linspace(0.01, 1.0, 50)
    votes = np.asarray(mcam.sa_votes(cur, cfg, th))
    assert (np.diff(votes) >= 0).all()
    assert votes.max() == cfg.n_thresholds


def test_hash_noise_deterministic_and_distributed():
    a = mcam.hash_normal(jnp.arange(10000, dtype=jnp.uint32), seed=7)
    b = mcam.hash_normal(jnp.arange(10000, dtype=jnp.uint32), seed=7)
    c = mcam.hash_normal(jnp.arange(10000, dtype=jnp.uint32), seed=8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.allclose(np.asarray(a), np.asarray(c))
    arr = np.asarray(a)
    assert abs(arr.mean()) < 0.05 and abs(arr.std() - 1.0) < 0.05


@pytest.mark.slow
def test_device_noise_perturbs_current():
    cfg = MCAMConfig(sigma_device=0.2, sigma_read=0.05)
    cells = jnp.ones((4, 24))
    idx = jnp.arange(4, dtype=jnp.uint32)
    noisy = mcam.string_current(cells, cfg, noise_idx=(idx,))
    clean = mcam.string_current(cells, cfg)
    assert not np.allclose(np.asarray(noisy), np.asarray(clean))
    # noise is zero-centred-ish: mean over many strings near clean value
    cells = jnp.ones((4096, 24))
    idx = jnp.arange(4096, dtype=jnp.uint32)
    noisy = mcam.string_current(cells, cfg, noise_idx=(idx,))
    assert abs(float(noisy.mean()) - float(clean[0])) < 0.05
