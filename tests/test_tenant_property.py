"""Property-based multi-tenant bit-parity (repro/engine/tenant.py, PR 9).

Random sweeps over (n_tenants, ragged capacity lists, k, mode, masked
rows, tie-heavy pools, query interleavings) pin two contracts the
deterministic twins in tests/test_tenant.py pin only pointwise:

* stack -> search parity: `search_tenants` over the stacked store equals
  per-tenant solo `engine.search` row-for-row (exact, including the
  rank-keyed noise coordinates and the (distance, index) lexicographic
  order under duplicated rows);
* stack -> tenant round-trip: `stack(stores).tenant(i)` reproduces
  `stores[i]` leaf-for-leaf under ANY ragged capacity list.

Skip-clean without hypothesis (it is not in the pinned environment; the
deterministic edge-case twins live in tests/test_tenant.py).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp                                        # noqa: E402
from hypothesis import HealthCheck, example, given, settings   # noqa: E402
from hypothesis import strategies as st                        # noqa: E402

from repro.core.avss import SearchConfig                       # noqa: E402
from repro.engine import (MemoryStore, RetrievalEngine,        # noqa: E402
                          SearchRequest, TenantStore)

CFG = SearchConfig("mtmc", cl=4, mode="avss", use_kernel="ref")
DIM = 10


def _stores(caps, masked, ties, seed):
    rng = np.random.default_rng(seed)
    out = []
    for c in caps:
        pool = rng.integers(0, CFG.enc.levels,
                            (max(1, c // 3) if ties else c, DIM))
        v = pool[rng.integers(0, pool.shape[0], c)]
        lab = rng.integers(0, 4, size=(c,))
        if masked:
            lab[rng.random(c) < 0.4] = -1
        out.append(MemoryStore.from_quantized(jnp.asarray(v),
                                              jnp.asarray(lab), CFG))
    return out


@settings(max_examples=20, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(caps=st.lists(st.integers(1, 14), min_size=1, max_size=6),
       kfrac=st.floats(0.1, 1.5),
       mode=st.sampled_from(["full", "two_phase", "ideal"]),
       backend=st.sampled_from(["ref", "mxu", "fused"]),
       masked=st.booleans(), ties=st.booleans(),
       seed=st.integers(0, 2 ** 16))
# maximally ragged + tie-heavy + masked, k beyond the smallest capacity
@example(caps=[1, 14, 3], kfrac=1.5, mode="two_phase", backend="fused",
         masked=True, ties=True, seed=7)
# single tenant degenerate case through the full (noisy dense) route
@example(caps=[5], kfrac=0.5, mode="full", backend="ref", masked=False,
         ties=False, seed=3)
def test_stack_search_parity_property(caps, kfrac, mode, backend, masked,
                                      ties, seed):
    rng = np.random.default_rng(seed)
    stores = _stores(caps, masked, ties, seed)
    tstore = TenantStore.stack(stores)
    k = max(1, round(kfrac * min(caps)))
    eng = RetrievalEngine(CFG)
    req = SearchRequest(mode=mode, k=k, backend=backend)

    b = int(rng.integers(1, 7))
    tids = rng.integers(0, len(caps), size=(b,))
    queries = jnp.asarray(rng.integers(0, 4, size=(b, DIM)), jnp.int32)
    res = eng.search_tenants(tstore, queries, jnp.asarray(tids, jnp.int32),
                             req)
    for t in range(len(caps)):
        sel = np.where(tids == t)[0]
        if not len(sel):
            continue
        solo = eng.search(stores[t], queries[jnp.asarray(sel)], req)
        width = caps[t] if mode == "full" else min(k, caps[t])
        for leaf in ("votes", "dist", "indices", "labels"):
            bres = getattr(res, leaf)
            if bres is None:
                assert getattr(solo, leaf) is None
                continue
            np.testing.assert_array_equal(
                np.asarray(bres[sel][:, :width]),
                np.asarray(getattr(solo, leaf)),
                err_msg=f"{mode}/{backend} tenant {t}: {leaf}")
        # columns past the tenant's own rows are masked pads, never rows
        # leaked from another tenant
        if res.votes.shape[1] > width:
            assert bool((res.votes[sel][:, width:] == -jnp.inf).all())


@settings(max_examples=20, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(caps=st.lists(st.integers(1, 20), min_size=1, max_size=8),
       masked=st.booleans(), seed=st.integers(0, 2 ** 16))
@example(caps=[20, 1, 1, 20], masked=True, seed=0)
def test_stack_tenant_round_trip_property(caps, masked, seed):
    stores = _stores(caps, masked, False, seed)
    tstore = TenantStore.stack(stores)
    assert tstore.n_pad == max(caps)
    assert tstore.capacities == tuple(caps)
    for i, s in enumerate(stores):
        t = tstore.tenant(i)
        for leaf in ("values", "proj", "proj_packed", "s_grid", "labels",
                     "size", "lo", "hi"):
            np.testing.assert_array_equal(
                np.asarray(getattr(t, leaf)), np.asarray(getattr(s, leaf)),
                err_msg=f"tenant {i}: {leaf}")
        assert t.cfg == s.cfg and t.calibrated == s.calibrated
        # pad rows beyond the tenant's capacity are label -1
        assert bool((tstore.labels[i, caps[i]:] == -1).all())
