"""Encoding coverage through the ENGINE paths (satellite of ISSUE 5).

The baseline encodings (B4E, B4WE, SRE) were configurable but effectively
untested beyond the raw encode/decode rules: this file runs each of them
through `engine.search` across the ref/mxu/fused backends and a sharded
store, asserting bit-parity, plus the paper-Table-1 `levels`/`words`
accounting. (Separate from tests/test_encodings.py, which module-skips
without hypothesis.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.avss import SearchConfig
from repro.core.encodings import CELL_STATES, make_encoding
from repro.engine import MemoryStore, RetrievalEngine, SearchRequest

# (name, cl, paper-Table-1 levels, words per dimension)
TABLE1 = [
    ("mtmc", 8, 3 * 8 + 1, 8),
    ("mtmc", 32, 97, 32),
    ("b4e", 3, CELL_STATES**3, 3),
    ("b4we", 2, CELL_STATES**2, (CELL_STATES**2 - 1) // 3),
    ("sre", 4, CELL_STATES, 4),
]


@pytest.mark.parametrize("name,cl,levels,words", TABLE1)
def test_levels_and_words_match_paper_table1(name, cl, levels, words):
    enc = make_encoding(name, cl)
    assert enc.levels == levels
    assert enc.length == words
    # every code word must fit one MLC cell
    v = jnp.arange(enc.levels)
    codes = np.asarray(enc.encode(v))
    assert codes.min() >= 0 and codes.max() <= CELL_STATES - 1
    # encode/decode round-trips every representable level
    np.testing.assert_array_equal(np.asarray(enc.decode(jnp.asarray(codes))),
                                  np.asarray(v))


ENGINE_ENCODINGS = [("mtmc", 8), ("b4e", 3), ("b4we", 2), ("sre", 4)]


def _store_and_queries(name, cl, n=48, d=16, b=5):
    cfg = SearchConfig(name, cl=cl, mode="avss", use_kernel="ref")
    sv = jax.random.randint(jax.random.PRNGKey(0), (n, d), 0,
                            cfg.enc.levels)
    qv = jax.random.randint(jax.random.PRNGKey(1), (b, d), 0, 4)
    labels = jnp.arange(n, dtype=jnp.int32) % 7
    return cfg, MemoryStore.from_quantized(sv, labels, cfg), qv


@pytest.mark.slow  # kernel-backend compile matrix: full tier
@pytest.mark.parametrize("name,cl", ENGINE_ENCODINGS)
@pytest.mark.parametrize("mode", ["two_phase", "ideal"])
def test_engine_backends_bit_identical_per_encoding(name, cl, mode):
    """ref / mxu / fused backends agree bitwise for every encoding, in
    both serving modes (votes, distances, candidate order, labels)."""
    cfg, store, qv = _store_and_queries(name, cl)
    req = SearchRequest(mode=mode, k=12)
    ref = RetrievalEngine(cfg, backend="ref").search(store, qv, req)
    for backend in ("mxu", "fused"):
        got = RetrievalEngine(cfg, backend=backend).search(store, qv, req)
        for field in ("votes", "dist", "indices", "labels"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, field)),
                np.asarray(getattr(got, field)),
                err_msg=f"{name}/{mode}/{backend}/{field}")


@pytest.mark.slow  # shard_map + fused-kernel compile matrix: full tier
@pytest.mark.parametrize("name,cl", ENGINE_ENCODINGS)
def test_sharded_store_bit_identical_per_encoding(name, cl):
    """A sharded store (ragged split included: 48 rows never divide a
    5-shard... here 1-dev mesh keeps the fast tier fast; the multi-device
    subprocess sweep lives in tests/test_engine.py) serves every encoding
    bit-identically to the unsharded search, ref and fused shortlists."""
    cfg, store, qv = _store_and_queries(name, cl)
    mesh = jax.make_mesh((1,), ("data",))
    sharded = store.shard(mesh)
    for backend in ("ref", "fused"):
        req = SearchRequest(mode="two_phase", k=12, backend=backend)
        want = RetrievalEngine(cfg, backend="ref").search(store, qv, req)
        got = RetrievalEngine(cfg).search(sharded, qv, req)
        for field in ("votes", "dist", "indices", "labels"):
            np.testing.assert_array_equal(
                np.asarray(getattr(want, field)),
                np.asarray(getattr(got, field)),
                err_msg=f"{name}/sharded/{backend}/{field}")


@pytest.mark.parametrize("name,cl", ENGINE_ENCODINGS)
def test_episode_votes_parity_per_encoding(name, cl):
    """The train/serve parity contract holds for the baseline encodings
    too (their identity-STE path still forwards the exact hard encode)."""
    cfg = SearchConfig(name, cl=cl, mode="avss", use_kernel="ref")
    eng = RetrievalEngine(cfg)
    s = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(2), (10, 12)))
    q = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(3), (4, 12)))
    ep = eng.episode_votes(q, s, noisy=False)
    from repro.core.memory import MemoryConfig
    mcfg = MemoryConfig(capacity=10, dim=12, search=cfg)
    store = MemoryStore.create(mcfg).calibrate(
        jnp.concatenate([s.ravel(), q.ravel()])).write(
            s, jnp.arange(10, dtype=jnp.int32))
    res = eng.search(store, q, SearchRequest(mode="full", noisy=False))
    np.testing.assert_array_equal(np.asarray(ep["votes"]),
                                  np.asarray(res.votes))
    np.testing.assert_array_equal(np.asarray(ep["dist"]),
                                  np.asarray(res.dist))
