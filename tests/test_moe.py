"""MoE layer: routing conservation, capacity behaviour, aux losses."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import load_config
from repro.models import moe as moe_lib


def _cfg(cf=8.0):
    cfg = load_config("deepseek-moe-16b", smoke=True)
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cf))


@pytest.mark.slow
def test_moe_forward_shapes_and_aux():
    cfg = _cfg()
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = moe_lib.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["load_balance"]) > 0
    assert float(aux["z_loss"]) >= 0


@pytest.mark.slow
def test_moe_high_capacity_processes_all_tokens():
    """With ample capacity, output == exact dense top-k mixture."""
    cfg = _cfg(cf=64.0)
    m = cfg.moe
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y, _ = moe_lib.moe_apply(p, x, cfg)
    # reference: per-token dense computation
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, m.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = []
    for t in range(xt.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(m.top_k):
            e = int(idx[t, j])
            h = jax.nn.silu(xt[t] @ p["we1"][e]) * (xt[t] @ p["we3"][e])
            acc += gate[t, j] * (h @ p["we2"][e])
        sh = p["shared"]
        acc += (jax.nn.silu(xt[t] @ sh["w1"]) * (xt[t] @ sh["w3"])) @ sh["w2"]
        ref.append(acc)
    ref = jnp.stack(ref).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


@pytest.mark.slow
def test_moe_capacity_drops_tokens():
    """Tiny capacity must change the output (tokens dropped)."""
    y_hi, _ = _run_cf(8.0)
    y_lo, _ = _run_cf(0.01)
    assert not np.allclose(y_hi, y_lo)


def _run_cf(cf):
    cfg = _cfg(cf)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, aux = moe_lib.moe_apply(p, x, cfg)
    return np.asarray(y), aux


@pytest.mark.slow
def test_moe_group_invariance():
    """Same tokens, different group counts => same output when capacity
    scales with group size (no drops)."""
    cfg = _cfg(cf=64.0)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    outs = []
    for g in (1, 2, 4):
        c = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                             groups=g))
        y, _ = moe_lib.moe_apply(p, x, c)
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-4)


@pytest.mark.slow
def test_balanced_router_low_aux():
    """Uniform routing => load_balance ~ 1 (its minimum)."""
    cfg = _cfg()
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform router
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    _, aux = moe_lib.moe_apply(p, x, cfg)
    assert 0.9 < float(aux["load_balance"]) < 1.3
