"""Contract guard (repro/analysis): HLO invariant registry + AST lint.

The registry is the ONE spelling of every HLO invariant -- test_store.py
and test_engine.py assert through the same `hlo_contracts` functions the
CLI walks, so a drifted spelling fails here before it can silently stop
matching in a test. The lint tests pin each rule's firing condition and
the suppression grammar on synthetic sources, then hold the real tree
clean.
"""

import json
import os
import textwrap

import jax
import pytest

from repro.analysis import hlo_contracts as hc
from repro.analysis import lint, registry
from repro.analysis.__main__ import main as analysis_main

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# HLO checkers: each catches an injected violation and passes clean text.
# ---------------------------------------------------------------------------


CLEAN_HLO = textwrap.dedent("""\
    HloModule jit_search
      fusion.1 = f32[5,16]{1,0} fusion(p0), kind=kLoop
      ROOT tuple.2 = (f32[5,16]) tuple(fusion.1)
""")


def test_checkers_catch_injected_violations():
    assert hc.check_no_collectives(CLEAN_HLO) == []
    for op in ("all-gather", "all-reduce", "all-to-all",
               "collective-permute"):
        bad = CLEAN_HLO + f"  ar.1 = f32[8] {op}(x), replica_groups={{}}\n"
        assert hc.check_no_collectives(bad), op
        with pytest.raises(AssertionError):
            hc.assert_no_collectives(bad)

    assert hc.check_no_scatter_any_spelling(CLEAN_HLO) == []
    for op in ("scatter(", "dynamic-update-slice"):
        bad = CLEAN_HLO + f"  s.1 = f32[8] {op}x)\n"
        assert hc.check_no_scatter_any_spelling(bad), op

    # scatter_write is the INVERTED contract: violation when absent
    assert hc.check_scatter_write(CLEAN_HLO)
    ok = CLEAN_HLO + "  dus.1 = f32[8] dynamic-update-slice(a, b, i)\n"
    assert hc.check_scatter_write(ok) == []

    tagged = CLEAN_HLO + "  f.2 = f32[5] fusion(x), name=\"shortlist_fused\"\n"
    assert hc.check_fused_tag(tagged, True) == []
    assert hc.check_fused_tag(tagged, False)
    assert hc.check_fused_tag(CLEAN_HLO, False) == []
    assert hc.check_fused_tag(CLEAN_HLO, True)

    layout = CLEAN_HLO + "  l.1 = s8[4] copy(x), name=\"layout_support\"\n"
    assert hc.check_no_layout_ops(CLEAN_HLO) == []
    assert hc.check_no_layout_ops(layout)
    assert hc.check_layout_ops_present(layout) == []
    assert hc.check_layout_ops_present(CLEAN_HLO)

    assert hc.check_no_f64(CLEAN_HLO) == []
    assert hc.check_no_f64(CLEAN_HLO + "  c.1 = f64[4] convert(x)\n")


# ---------------------------------------------------------------------------
# AST lint: every rule fires on a synthetic source; suppression works.
# ---------------------------------------------------------------------------


def _rules(source, path):
    return sorted({f.rule for f in lint.lint_source(
        textwrap.dedent(source), path)})


def test_lint_deprecated_shim():
    src = """
        from repro.core.memory import search
        from repro.core import memory
        memory.distributed_search(None, None)
    """
    assert _rules(src, "src/repro/models/x.py") == ["deprecated-shim"]
    # the shims' own module is exempt
    assert _rules(src, "src/repro/core/memory.py") == []


def test_lint_kernel_sort_through_partial():
    src = """
        import functools, jax
        from jax.experimental import pallas as pl
        def _kern(ref, o_ref):
            o_ref[...] = jax.lax.top_k(ref[...], 4)[0]
        def run(x):
            k = functools.partial(_kern)
            return pl.pallas_call(k, out_shape=None)(x)
    """
    assert _rules(src, "src/repro/kernels/k.py") == ["kernel-sort"]
    # annotated interpret-only branch is allowed (line-above form)
    ok = src.replace("o_ref[...] = ",
                     "# lint: allow=kernel-sort\n            o_ref[...] = ")
    assert "kernel-sort" not in _rules(ok, "src/repro/kernels/k.py")


def test_lint_serving_path_rules():
    src = """
        import jax
        def f(x):
            noise = jax.random.normal(jax.random.PRNGKey(0), x.shape)
            return x + noise + 1e-6
    """
    found = _rules(src, "src/repro/engine/e.py")
    assert found == ["float-epsilon-tiebreak", "serving-raw-random"]
    # outside serving paths neither rule applies
    assert _rules(src, "src/repro/data/d.py") == []
    # key_data is introspection, not sampling
    assert _rules("import jax\nx = jax.random.key_data",
                  "src/repro/engine/e.py") == []


def test_lint_ste_and_f64():
    src = """
        from repro.core.quantization import _ste_round_fwd
        y = x.astype("float64")
    """
    assert _rules(src, "src/repro/models/m.py") == ["f64-astype",
                                                    "ste-raw-primitive"]
    # the defining modules may touch their own primitives
    assert _rules("from repro.core.quantization import _ste_round_fwd",
                  "src/repro/core/quantization.py") == []


def test_lint_cost_call():
    src = """
        def f(compiled):
            c = compiled.cost_analysis()
            m = compiled.memory_analysis()
            return c, m
    """
    assert _rules(src, "src/repro/launch/d.py") == ["cost-call"]
    # the cost model's own package is exempt (it IS the one spelling)
    assert _rules(src, "src/repro/analysis/cost.py") == []
    # suppression names the rule
    ok = src.replace("compiled.cost_analysis()",
                     "compiled.cost_analysis()  # lint: allow=cost-call")
    ok = ok.replace("compiled.memory_analysis()",
                    "compiled.memory_analysis()  # lint: allow=cost-call")
    assert _rules(ok, "src/repro/launch/d.py") == []


def test_lint_trailing_suppression():
    src = 'import jax\nn = jax.random.normal  # lint: allow=serving-raw-random\n'
    assert lint.lint_source(src, "src/repro/engine/e.py") == []


def test_repo_tree_is_lint_clean():
    findings = lint.lint_paths([os.path.join(ROOT, "src", "repro")])
    assert findings == [], "\n".join(f.format() for f in findings)


# ---------------------------------------------------------------------------
# Registry: the matrix covers every route; small cells pass end to end.
# ---------------------------------------------------------------------------


def test_registry_matrix_covers_every_route():
    cells = registry.build_cells()
    assert len(cells) >= 30          # ~119 invariant rows under the CLI
    for cell in cells:
        for inv in cell.invariants:
            assert inv in registry.INVARIANTS, inv
    search = [c for c in cells if c.entry == "engine.search"
              and "mode" in c.config]
    routed = [c for c in search if "nprobe" in c.config]
    search = [c for c in search if "nprobe" not in c.config]
    modes = {c.config["mode"] for c in search}
    backends = {c.config["backend"] for c in search}
    assert modes == {"full", "two_phase", "ideal"}
    assert backends == {"ref", "mxu", "fused"}
    assert {c.config["sharded"] for c in search} == {True, False}
    assert {c.config["packed"] for c in search} == {True, False}
    # both sides of the fused dispatch are forced somewhere in the matrix
    fmrs = {c.config["fused_min_rows"] for c in search}
    assert {registry.FMR_FORCE_FUSED, registry.FMR_FORCE_DENSE} <= fmrs
    # routed cells (PR 10): both phase-1 dispositions engaged, both packed
    # sides, plus the nprobe == n_shards control with the tag-absent check
    assert {c.config["backend"] for c in routed} >= {"mxu", "fused"}
    assert {c.config["packed"] for c in routed} == {True, False}
    assert any(c.config["nprobe"] == c.config["n_shards"] for c in routed)
    assert any(c.config["nprobe"] < c.config["n_shards"] for c in routed)
    for c in routed:
        assert "router_tag_iff_engaged" in c.invariants, c.config
        assert "no_collectives" in c.invariants, c.config
    writes = {c.config["path"] for c in cells
              if c.entry == "MemoryStore.write"}
    assert writes == {"unsharded", "one_shard", "multi_shard"}
    assert any(c.entry == "episode_votes" for c in cells)
    # every fused-expected unsharded ideal cell carries the HBM bound
    for c in search:
        if (c.config["mode"] == "ideal" and not c.config["sharded"]
                and registry._expect_fused(c.config["backend"], 72, "ideal",
                                           c.config["fused_min_rows"])):
            assert "hbm_buffer_bound" in c.invariants, c.config


def test_registry_sharded_cells_skip_without_devices():
    cell = registry._search_cell("ideal", "mxu", 1, True, True,
                                 len(jax.devices()) + 1)
    assert cell.skip
    report = registry.run_cells([cell])
    assert report["summary"]["skip"] == len(cell.invariants)
    assert report["summary"]["fail"] == 0


def test_registry_small_subset_passes():
    """A cheap unsharded slice of the matrix compiles and passes in-process
    (the full matrix runs via `python -m repro.analysis run` in CI)."""
    cells = [
        registry._search_cell("two_phase", "fused", registry.FMR_FORCE_FUSED,
                              True, False, 1),
        registry._write_cell("unsharded", 1),
        registry._layout_control_cell(),
    ]
    report = registry.run_cells(cells)
    assert report["summary"]["fail"] == 0, report["cells"]
    assert report["summary"]["error"] == 0, report["cells"]
    assert report["summary"]["pass"] == sum(len(c.invariants) for c in cells)
    # rows carry what the CLI prints and the diff keys on
    for row in report["cells"]:
        assert {"entry", "config", "invariant", "status", "detail",
                "matched"} <= set(row)


def test_registry_detects_broken_invariant():
    """A cell whose artifacts violate its invariant FAILS (the runner is
    not a rubber stamp): feed the inverted expectation to a real cell."""
    cell = registry._search_cell("two_phase", "fused",
                                 registry.FMR_FORCE_FUSED, True, False, 1)
    art = cell.build()
    assert art["expect_fused"] is True
    assert registry.INVARIANTS["fused_tag_iff_dispatch_rule"](
        {"hlo": art["hlo"], "expect_fused": False})


# ---------------------------------------------------------------------------
# CLI: lint and diff exit codes (run is exercised by CI on every push).
# ---------------------------------------------------------------------------


def test_cli_lint_exit_codes(tmp_path, capsys):
    bad = tmp_path / "repro" / "engine" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import jax\nn = jax.random.normal\n")
    assert analysis_main(["lint", str(bad)]) == 1
    assert "serving-raw-random" in capsys.readouterr().out
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert analysis_main(["lint", str(good)]) == 0


def _report(failing_keys):
    return {"meta": {}, "summary": {},
            "cells": [{"entry": e, "config": {}, "invariant": i,
                       "status": "fail", "detail": "", "matched": []}
                      for e, i in failing_keys]}


def test_cli_diff_new_failure_is_red(tmp_path, capsys):
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    old.write_text(json.dumps(_report([("a", "no_f64_promotion")])))
    new.write_text(json.dumps(_report([("a", "no_f64_promotion"),
                                       ("b", "no_collectives")])))
    assert analysis_main(["diff", str(old), str(new)]) == 1
    assert "NEW FAILURE" in capsys.readouterr().out
    # failures fixed (or merely pre-existing) are green
    assert analysis_main(["diff", str(new), str(old)]) == 0
    assert "fixed" in capsys.readouterr().out
