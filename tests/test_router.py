"""Hierarchical routing suite (repro/engine/router.py, PR 10).

The tentpole's contract, part (a): `SearchRequest.nprobe=p` on a
partitioned store scores the write-time per-shard sketch with one small
matmul and dispatches phase 1/2 to the top-p shards only, and the result
is BIT-IDENTICAL to the exhaustive search restricted to the visited
shards -- same SHORTLIST_MASK_PENALTY, same (distance, index) lex merge,
two-phase votes keyed on the same GLOBAL (query, row) noise coordinates.
`nprobe=None` (and `nprobe >= n_shards`) must reproduce today's
exhaustive sharded search byte-for-byte.

The fixture is deliberately tie-heavy (every row repeated 9x across the
shard boundary) so only an exact (distance, global index) lexicographic
merge over the visited blocks can pass, and it carries masked label -1
rows that land inside the top-k.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.avss import SearchConfig
from repro.engine import MemoryStore, RetrievalEngine, SearchRequest
from repro.engine import router as router_lib

N_SHARDS = 8
ROWS = 72          # 9 rows/shard
DIM = 20
K = 12


def _cfg(backend="ref"):
    return SearchConfig("mtmc", cl=8, mode="avss", use_kernel=backend)


@pytest.fixture(scope="module")
def routed_fixture():
    """(store_by_backend, queries): the 72-row tie-heavy partitioned store
    on each backend config, plus 5 pre-quantized queries."""
    rng = np.random.default_rng(0)
    base = rng.integers(0, 16, (8, DIM))
    vals = jnp.asarray(np.concatenate([base] * 9))           # ties galore
    labs = np.arange(ROWS) % 9
    labs[labs % 4 == 0] = -1                                 # masked rows
    labs = jnp.asarray(labs)
    q = jnp.asarray(rng.integers(0, 4, (5, DIM)))
    stores = {}
    for backend in ("ref", "mxu", "fused"):
        stores[backend] = MemoryStore.from_quantized(
            vals, labs, _cfg(backend)).shard(n_shards=N_SHARDS)
    return stores, q


def _leaves(res):
    return {f: np.asarray(getattr(res, f))
            for f in ("votes", "dist", "indices", "labels")}


@pytest.mark.parametrize("backend", ["ref", "mxu", "fused"])
@pytest.mark.parametrize("mode", ["two_phase", "ideal"])
@pytest.mark.parametrize("nprobe", [1, 3, 5])
def test_routed_bit_identical_to_restricted_brute_force(
        routed_fixture, backend, mode, nprobe):
    """Routed == exhaustive search filtered to the visited shards, per
    query, on every leaf -- including two-phase votes (global noise
    coordinates) and distances (exact integers, so equality is exact)."""
    stores, q = routed_fixture
    store = stores[backend]
    eng = RetrievalEngine(store.cfg.search)
    fmr = 1 if backend == "fused" else None    # force the fused kernel
    routed = eng.search(store, q, SearchRequest(
        mode=mode, k=K, nprobe=nprobe, fused_min_rows=fmr))

    # the reference: the FULL search ranked over all rows, then filtered
    # to the rows of the router's visited shards
    full = eng.search(store, q, SearchRequest(
        mode=mode, k=store.capacity, fused_min_rows=fmr))
    scores = router_lib.route_scores(
        store.quantize_queries(q), store.sketch_sums, store.sketch_counts,
        store.cfg.search.enc)
    sids = np.asarray(router_lib.top_shards(scores, nprobe))
    rows = store.capacity // N_SHARDS
    got, ref = _leaves(routed), _leaves(full)
    for b in range(q.shape[0]):
        shard_of_row = ref["indices"][b] // rows
        keep = np.isin(shard_of_row, sids[b])
        for f in ("dist", "indices", "labels", "votes"):
            np.testing.assert_array_equal(
                got[f][b], ref[f][b][keep][:K],
                err_msg=f"{backend}/{mode}/nprobe={nprobe}: {f}[{b}]")


@pytest.mark.parametrize("mode", ["two_phase", "ideal"])
def test_nprobe_none_and_all_shards_byte_identical(routed_fixture, mode):
    """nprobe=None, nprobe=n_shards and nprobe>n_shards are the SAME
    exhaustive program -- byte-identical results."""
    stores, q = routed_fixture
    store = stores["mxu"]
    eng = RetrievalEngine(store.cfg.search)
    base = eng.search(store, q, SearchRequest(mode=mode, k=K))
    for p in (N_SHARDS, N_SHARDS + 3):
        alt = eng.search(store, q, SearchRequest(mode=mode, k=K, nprobe=p))
        for f, v in _leaves(base).items():
            np.testing.assert_array_equal(v, _leaves(alt)[f], err_msg=f)


def test_nprobe_on_unpartitioned_store_is_exhaustive(routed_fixture):
    """n_shards=1: any nprobe >= 1 is the plain unsharded search."""
    _, q = routed_fixture
    rng = np.random.default_rng(3)
    store = MemoryStore.from_quantized(
        jnp.asarray(rng.integers(0, 16, (24, DIM))),
        jnp.asarray(rng.integers(0, 5, (24,))), _cfg("mxu"))
    eng = RetrievalEngine(store.cfg.search)
    a = eng.search(store, q, SearchRequest(mode="two_phase", k=6))
    b = eng.search(store, q, SearchRequest(mode="two_phase", k=6, nprobe=1))
    for f, v in _leaves(a).items():
        np.testing.assert_array_equal(v, _leaves(b)[f], err_msg=f)


def test_router_prefers_the_matching_shard():
    """A query equal to one shard's class centroid routes there first."""
    cfg = _cfg("ref")
    # shard 0: rows near level 2; shard 1: rows near level 13
    vals = jnp.asarray([[2] * DIM] * 4 + [[13] * DIM] * 4)
    labs = jnp.asarray([0] * 4 + [1] * 4)
    store = MemoryStore.from_quantized(vals, labs, cfg).shard(n_shards=2)
    scores = router_lib.route_scores(
        jnp.asarray([[0] * DIM, [3] * DIM]),   # low words vs high words
        store.sketch_sums, store.sketch_counts, cfg.enc)
    sids = np.asarray(router_lib.top_shards(scores, 1))
    assert sids[0, 0] == 0 and sids[1, 0] == 1
    # ...and nprobe=1 retrieval then hits the right class
    eng = RetrievalEngine(cfg)
    res = eng.search(store, jnp.asarray([[0] * DIM, [3] * DIM]),
                     SearchRequest(mode="ideal", k=2, nprobe=1))
    assert np.asarray(res.predict()).tolist() == [0, 1]


def test_sketch_tracks_scatter_writes_through_wraparound():
    """The write-path sketch (incremental S=1 delta) equals a from-scratch
    rebuild after ring writes that overwrite and wrap."""
    cfg = _cfg("ref")
    rng = np.random.default_rng(1)
    from repro.core.memory import MemoryConfig
    mc = MemoryConfig(capacity=12, dim=DIM, search=cfg)
    sample = jnp.asarray(rng.normal(size=(8, DIM)), jnp.float32)
    store = MemoryStore.create(mc).calibrate(sample)
    for n in (5, 5, 7):                       # 17 rows > capacity: wraps
        v = jnp.asarray(rng.normal(size=(n, DIM)), jnp.float32)
        lab = jnp.asarray(rng.integers(-1, 6, (n,)))
        store = store.write(v, lab)
        want_s, want_c = router_lib.build_sketch(store.values, store.labels,
                                                 1)
        np.testing.assert_array_equal(np.asarray(store.sketch_sums),
                                      np.asarray(want_s))
        np.testing.assert_array_equal(np.asarray(store.sketch_counts),
                                      np.asarray(want_c))


def test_sketch_tracks_writes_on_partitioned_store():
    """Writes on a logically partitioned store rebuild the per-shard
    sketch exactly (full-rebuild path)."""
    rng = np.random.default_rng(2)
    from repro.core.memory import MemoryConfig
    mc = MemoryConfig(capacity=32, dim=DIM, search=_cfg("ref"))
    sample = jnp.asarray(rng.normal(size=(16, DIM)), jnp.float32)
    store = (MemoryStore.create(mc).calibrate(sample)
             .write(sample, jnp.asarray(rng.integers(0, 6, (16,))))
             .shard(n_shards=N_SHARDS))
    store = store.write(
        jnp.asarray(rng.normal(size=(6, DIM)), jnp.float32),
        jnp.asarray(rng.integers(0, 6, (6,))))
    want_s, want_c = router_lib.build_sketch(
        store.values, store.labels, N_SHARDS)
    np.testing.assert_array_equal(np.asarray(store.sketch_sums),
                                  np.asarray(want_s))
    np.testing.assert_array_equal(np.asarray(store.sketch_counts),
                                  np.asarray(want_c))


def test_request_validation():
    with pytest.raises(ValueError, match="nprobe routes the shortlist"):
        SearchRequest(mode="full", nprobe=2)
    with pytest.raises(ValueError, match="nprobe must be >= 1"):
        SearchRequest(mode="ideal", nprobe=0)


def test_host_residency_must_go_through_the_pager(routed_fixture):
    stores, q = routed_fixture
    host = stores["ref"]._unpad().shard(n_shards=4, residency="host")
    eng = RetrievalEngine(host.cfg.search)
    with pytest.raises(ValueError, match="ShardPager"):
        eng.search(host, q, SearchRequest(mode="ideal", k=4, nprobe=2))


def test_empty_shard_never_outranks_real_rows():
    """A shard of pure label -1 padding carries the mask penalty in the
    sketch and is routed LAST."""
    cfg = _cfg("ref")
    rng = np.random.default_rng(4)
    vals = jnp.asarray(rng.integers(0, 16, (12, DIM)))
    labs = jnp.asarray([3] * 6 + [-1] * 6)    # shard 1 is all masked
    store = MemoryStore.from_quantized(vals, labs, cfg).shard(n_shards=2)
    scores = router_lib.route_scores(
        jnp.asarray(rng.integers(0, 4, (3, DIM))),
        store.sketch_sums, store.sketch_counts, cfg.enc)
    sids = np.asarray(router_lib.top_shards(scores, 1))
    assert (sids == 0).all()
