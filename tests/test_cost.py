"""Resource oracle (repro/analysis/cost): the ONE cost model.

Layer by layer: the HLO op census on golden fixtures, the cost_analysis
read/write split, the while-loop trip-count correction on an exact
synthetic model, the dry-run launcher's delegation (identity, not
re-implementation), the per-route resource report on cheap registry
cells, and the `cost-diff` CLI gate's exit codes on injected drift.
"""

import json
import textwrap

import jax

from repro.analysis import cost, registry
from repro.analysis.__main__ import main as analysis_main


# ---------------------------------------------------------------------------
# HLO-text extraction: golden census fixtures.
# ---------------------------------------------------------------------------


CENSUS_HLO = textwrap.dedent("""\
    HloModule jit_step
      %dot.1 = f32[8,128]{1,0} dot(p0, p1), lhs_contracting_dims={1}
      %cvt.1 = bf16[8,128]{1,0} convert(dot.1)
      %ag.1 = f32[16,64]{1,0} all-gather(p2), replica_groups={}
      %ars.1 = f32[16,64]{1,0} all-reduce-start(p5), replica_groups={}
      %g.1 = f32[4,4]{1,0} gather(p3, p4), offset_dims={1}
      %w.1 = (s32[], f32[8]) while(t0), condition=%cond, body=%body
      %sort.1 = f32[8,128]{1,0} sort(cvt.1), dimensions={1}
      %dus.1 = f32[8,16]{1,0} dynamic-update-slice(a, b, i0, i1)
      %c.1 = f32[2,2]{1,0} add(x, y)
""")


def test_hlo_op_census_golden():
    c = cost.hlo_op_census(CENSUS_HLO)
    assert c["dot"] == {"count": 1, "bytes": 8 * 128 * 4}
    assert c["convert"] == {"count": 1, "bytes": 8 * 128 * 2}
    assert c["all-gather"] == {"count": 1, "bytes": 16 * 64 * 4}
    # the -start spelling of an async collective still counts
    assert c["all-reduce"] == {"count": 1, "bytes": 16 * 64 * 4}
    # the all-gather line is a collective, NOT a plain gather: one match
    # per line, most specific first
    assert c["gather"] == {"count": 1, "bytes": 4 * 4 * 4}
    assert c["while"]["count"] == 1
    assert c["sort"] == {"count": 1, "bytes": 8 * 128 * 4}
    assert c["dynamic-update-slice"] == {"count": 1, "bytes": 8 * 16 * 4}
    # untracked ops (plain add) never appear
    assert "add" not in c
    assert "scatter" not in c


def test_shape_bytes_tokens():
    assert cost.shape_bytes("f32[8,128]") == 8 * 128 * 4
    assert cost.shape_bytes("bf16[16]") == 32
    assert cost.shape_bytes("s32[]") == 4          # scalar
    assert cost.shape_bytes("weird[8]") == 0       # unknown dtype
    assert cost.shape_bytes("nonsense") == 0


# ---------------------------------------------------------------------------
# cost_analysis extraction: read/write split + list-valued handling.
# ---------------------------------------------------------------------------


def test_hbm_rw_bytes_operand_terms():
    c = {"bytes accessed": 1000.0, "bytes accessed0{}": 600.0,
         "bytes accessed1{}": 200.0, "bytes accessedout{}": 200.0}
    assert cost.hbm_rw_bytes(c) == (800.0, 200.0)


def test_hbm_rw_bytes_fallback_without_operand_terms():
    c = {"bytes accessed": 1000.0, "bytes accessedout{}": 300.0}
    assert cost.hbm_rw_bytes(c) == (700.0, 300.0)
    assert cost.hbm_rw_bytes({}) == (0.0, 0.0)


def test_compiled_cost_handles_per_device_list():
    class FakeCompiled:
        def cost_analysis(self):
            return [{"flops": 42.0, "bytes accessed": 7, "utilization": {}}]

    c = cost.compiled_cost(FakeCompiled())
    assert c == {"flops": 42.0, "bytes accessed": 7.0}


# ---------------------------------------------------------------------------
# Trip-count correction: exact on a synthetic affine cost model.
# ---------------------------------------------------------------------------


def _affine(counts, accum, fixed=1000.0, micro=7.0, per_layer=(10.0, 100.0)):
    """M(counts, A) = fixed + A*(micro + sum_g counts_g * f_g) -- the shape
    XLA's once-per-while-body counting gives an unrolled variant."""
    inner = micro + sum(c * f for c, f in zip(counts, per_layer))
    return {"flops": fixed + accum * inner}


def test_scan_trip_count_totals_exact_with_accumulation():
    m1 = _affine((1, 1), 1)                       # 1117
    m2 = [_affine((2, 1), 1), _affine((1, 2), 1)]  # 1127, 1217
    m3 = _affine((1, 1), 2)                       # 1234
    got = cost.scan_trip_count_totals(m1, m2, counts=(3, 5), accum=4, m3=m3)
    # true totals: 1000 + 4*(7 + 3*10 + 5*100) = 3148
    assert got["flops"] == 3148.0


def test_scan_trip_count_totals_exact_without_accumulation():
    m1 = _affine((1, 1), 1)
    m2 = [_affine((2, 1), 1), _affine((1, 2), 1)]
    got = cost.scan_trip_count_totals(m1, m2, counts=(3, 5), accum=1)
    # micro folds into fixed when A == 1: 1007 + 3*10 + 5*100 = 1537
    assert got["flops"] == 1000.0 + 7.0 + 3 * 10.0 + 5 * 100.0


def test_scan_trip_count_clamps_negative_differences():
    m1 = {"flops": 100.0}
    m2 = [{"flops": 90.0}]                        # variant folded smaller
    got = cost.scan_trip_count_totals(m1, m2, counts=(4,), accum=1)
    assert got["flops"] >= 0.0


# ---------------------------------------------------------------------------
# The dry-run launcher DELEGATES (identity, not a copy).
# ---------------------------------------------------------------------------


def test_dryrun_is_a_thin_delegate():
    from repro.launch import dryrun
    assert dryrun.parse_collectives is cost.parse_collectives
    assert dryrun._shape_bytes is cost.shape_bytes
    assert dryrun._metrics is cost.roofline_metrics
    assert dryrun._COLLECTIVES is cost.COLLECTIVE_KINDS


def test_parse_collectives_all_reduce_doubles():
    hlo = ("HloModule m\n"
           "  a = f32[256]{0} all-reduce(x), replica_groups={}\n"
           "  b = f32[256]{0} all-gather(y), replica_groups={}\n")
    coll = cost.parse_collectives(hlo)
    assert coll["all-reduce"] == {"count": 1, "bytes": 2 * 256 * 4}
    assert coll["all-gather"] == {"count": 1, "bytes": 256 * 4}
    assert coll["total_bytes"] == 3 * 256 * 4


# ---------------------------------------------------------------------------
# The per-route resource report over registry cells.
# ---------------------------------------------------------------------------


def test_resource_report_cheap_cells():
    cells = [
        registry._layout_control_cell(),
        registry._write_cell("unsharded", 1),
        # a cell needing more devices than available -> a skip row, not
        # a hole in the report
        registry._search_cell("ideal", "mxu", 1, True, True,
                              len(jax.devices()) + 1),
    ]
    report = cost.resource_report(cells)
    assert report["summary"]["routes"] == 3
    assert report["summary"]["ok"] == 2
    assert report["summary"]["skip"] == 1
    assert report["summary"]["error"] == 0
    json.dumps(report)                     # artifact must serialise as-is

    ok_rows = [r for r in report["routes"] if r["status"] == "ok"]
    for r in ok_rows:
        assert r["flops"] is not None and r["flops"] >= 0.0
        assert r["jit_entries"] == 1
        assert r["op_census"], "compiled cells carry an op census"
        assert r["peak_bytes"] >= r["temp_bytes"]
    # the search control cell does real MXU work
    layout = next(r for r in ok_rows
                  if r["entry"] == "engine.two_phase(raw-arrays)")
    assert layout["flops"] > 0
    assert layout["hbm_bytes_read"] > 0
    assert layout["hbm_bytes_written"] > 0
    skip = next(r for r in report["routes"] if r["status"] == "skip")
    assert skip["flops"] is None and skip["detail"]


def test_resource_report_jit_cache_entries():
    report = cost.resource_report([registry._jit_cache_cell()])
    (row,) = report["routes"]
    assert row["status"] == "ok"
    # no compiled program on this cell: the measured cache size IS the
    # route's jit_entries, everything else stays null
    assert row["jit_entries"] == 1
    assert row["flops"] is None


def test_route_key_matches_registry_cell_key():
    cell = registry._write_cell("unsharded", 1)
    row = cost._null_row(cell.entry, cell.config, "ok", "")
    assert cost.route_key(row) == cell.key


# ---------------------------------------------------------------------------
# cost-diff: the drift gate's exit codes on synthetic reports.
# ---------------------------------------------------------------------------


def _rrow(entry, **over):
    row = {"entry": entry, "config": {}, "status": "ok", "detail": "",
           "flops": 100.0, "hbm_bytes_read": 1000.0,
           "hbm_bytes_written": 500.0, "temp_bytes": 64,
           "peak_bytes": 2048, "jit_entries": 1, "op_census": {},
           "while_ops": 0}
    row.update(over)
    return row


def _write(path, rows):
    path.write_text(json.dumps(
        {"meta": {}, "summary": {}, "routes": rows}))


def test_cli_cost_diff_exit_codes(tmp_path, capsys):
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    _write(old, [_rrow("a")])

    _write(new, [_rrow("a")])                      # identical: green
    assert analysis_main(["cost-diff", str(old), str(new)]) == 0

    _write(new, [_rrow("a", flops=110.0)])         # 10% > rtol 5%: red
    assert analysis_main(["cost-diff", str(old), str(new)]) == 1
    assert "DRIFT" in capsys.readouterr().out

    _write(new, [_rrow("a", flops=103.0)])         # 3% < rtol 5%: green
    assert analysis_main(["cost-diff", str(old), str(new)]) == 0

    _write(new, [])                                # lost route: red
    assert analysis_main(["cost-diff", str(old), str(new)]) == 1
    assert "MISSING ROUTE" in capsys.readouterr().out

    _write(new, [_rrow("a"), _rrow("b")])          # growth only: green
    assert analysis_main(["cost-diff", str(old), str(new)]) == 0
    assert "added" in capsys.readouterr().out

    _write(new, [_rrow("a", jit_entries=2)])       # jit_entries is exact
    assert analysis_main(["cost-diff", str(old), str(new)]) == 1

    # a route degrading to error status counts as missing, not silently ok
    _write(new, [_rrow("a", status="error")])
    assert analysis_main(["cost-diff", str(old), str(new)]) == 1


def test_diff_wider_rtol_tolerates_more():
    oldr = {"routes": [_rrow("a")]}
    newr = {"routes": [_rrow("a", flops=110.0)]}
    assert cost.diff_resource_reports(oldr, newr, rtol=0.05)["drifted"]
    assert not cost.diff_resource_reports(oldr, newr, rtol=0.2)["drifted"]
