"""Hardware-aware training: STE gradients, asymmetric QAT, learnability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hat
from repro.core.avss import SearchConfig
from repro.core.hat import HATConfig, mtmc_word_ste, simulate_mcam, ste_step
from repro.core.mcam import MCAMConfig
from repro.core.quantization import quantize_asymmetric, ste_round


def test_ste_round_gradient_is_identity():
    g = jax.grad(lambda x: jnp.sum(ste_round(x) * 3.0))(jnp.array([0.2, 1.7]))
    np.testing.assert_allclose(np.asarray(g), [3.0, 3.0])


def test_ste_step_sigmoid_gradient():
    f = lambda x: ste_step(x, 0.1).sum()
    y = ste_step(jnp.array([-1.0, 0.5]), 0.1)
    np.testing.assert_array_equal(np.asarray(y), [0.0, 1.0])
    g = jax.grad(f)(jnp.array([0.0]))
    np.testing.assert_allclose(np.asarray(g), [0.25 / 0.1], rtol=1e-5)


def test_mtmc_word_ste_forward_exact_backward_slope():
    cl = 8
    v = jnp.arange(25, dtype=jnp.float32)
    from repro.core.encodings import make_encoding
    enc = make_encoding("mtmc", cl)
    hard = np.asarray(enc.encode(v.astype(jnp.int32)))
    for c in range(cl):
        word = mtmc_word_ste(v, c, cl)
        np.testing.assert_array_equal(np.asarray(word), hard[:, c])
        g = jax.grad(lambda x: mtmc_word_ste(x, c, cl).sum())(v)
        np.testing.assert_allclose(np.asarray(g), 1.0 / cl)


def test_asymmetric_quant_levels():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (64,))
    s = jax.random.normal(jax.random.PRNGKey(1), (256,))
    qq, qs = quantize_asymmetric(q, s, support_levels=25)
    assert np.asarray(qq).max() <= 3 and np.asarray(qq).min() >= 0
    assert np.asarray(qs).max() <= 24 and np.asarray(qs).min() >= 0
    assert len(np.unique(np.asarray(qs))) > 4  # finer support grid


@pytest.mark.slow
def test_simulate_mcam_gradients_nonzero():
    hcfg = HATConfig(search=SearchConfig(encoding="mtmc", cl=4, mode="avss"))
    B, N, dim, nway = 4, 10, 12, 5
    q = jax.random.normal(jax.random.PRNGKey(0), (B, dim))
    s = jax.random.normal(jax.random.PRNGKey(1), (N, dim))
    labels = jnp.arange(N) % nway

    def loss(q, s):
        sc = simulate_mcam(q, s, labels, nway, hcfg, jax.random.PRNGKey(2))
        return hat.cross_entropy(sc / hcfg.temperature,
                                 jnp.zeros((B,), jnp.int32))

    gq, gs = jax.grad(loss, argnums=(0, 1))(q, s)
    assert float(jnp.linalg.norm(gq)) > 0
    assert float(jnp.linalg.norm(gs)) > 0


@pytest.mark.slow
def test_hat_training_improves_episode_accuracy():
    """Meta-training a linear controller THROUGH the noisy MCAM simulator
    improves held-out episode accuracy (HAT learns hardware-robust
    features). Measured on fixed eval episodes: ~0.73 -> ~0.88."""
    hcfg = HATConfig(search=SearchConfig(
        encoding="mtmc", cl=4, mode="avss",
        mcam=MCAMConfig(sigma_device=0.3, sigma_read=0.1)))
    dim, nway, kshot, nq = 6, 4, 4, 16
    centers = jax.random.normal(jax.random.PRNGKey(0), (nway, 16))
    W0 = jax.random.normal(jax.random.PRNGKey(1), (16, dim)) * 0.02
    apply_fn = lambda p, x: jax.nn.relu(x @ p)

    def episode(key):
        ks, kq = jax.random.split(key)
        s_lab = jnp.repeat(jnp.arange(nway), kshot)
        q_lab = jnp.repeat(jnp.arange(nway), nq // nway)
        s_x = centers[s_lab] + 0.8 * jax.random.normal(ks, (len(s_lab), 16))
        q_x = centers[q_lab] + 0.8 * jax.random.normal(kq, (len(q_lab), 16))
        return s_x, s_lab, q_x, q_lab

    def loss_fn(W, ep, key):
        s_x, s_lab, q_x, q_lab = ep
        sc = simulate_mcam(apply_fn(W, q_x), apply_fn(W, s_x), s_lab, nway,
                           hcfg, key)
        return hat.cross_entropy(sc / hcfg.temperature, q_lab)

    def accuracy(W, ep, key):
        s_x, s_lab, q_x, q_lab = ep
        sc = simulate_mcam(apply_fn(W, q_x), apply_fn(W, s_x), s_lab, nway,
                           hcfg, key)
        return float((jnp.argmax(sc, -1) == q_lab).mean())

    evals = [episode(jax.random.PRNGKey(5000 + i)) for i in range(8)]
    eval_all = lambda W: np.mean(
        [accuracy(W, e, jax.random.PRNGKey(77)) for e in evals])
    jgrad = jax.jit(jax.value_and_grad(loss_fn))
    before = eval_all(W0)
    w, m = W0, jnp.zeros_like(W0)
    for i in range(60):
        _, g = jgrad(w, episode(jax.random.PRNGKey(100 + i)),
                     jax.random.PRNGKey(i))
        m = 0.9 * m + g
        w = w - 0.05 * m
    after = eval_all(w)
    assert after > before + 0.05, (before, after)
