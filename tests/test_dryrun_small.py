"""Dry-run machinery internals (pure functions; the compile-path is covered
by tests/test_distributed.py::test_dryrun_cell_small_mesh)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.dryrun import _shape_bytes, model_flops, parse_collectives
from repro.models.sharding import legalize_spec


HLO = """
ENTRY %main {
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %p0), dims={0}
  %ar = f32[4,4096]{1,0} all-reduce(f32[4,4096]{1,0} %p1), to_apply=%sum
  %rs = f32[2,8]{1,0} reduce-scatter(f32[2,128]{1,0} %p2), dimensions={1}
  %cp = bf16[8]{0} collective-permute(bf16[8]{0} %p3), source_target_pairs={{0,1}}
  %ags = (f32[32,32]{1,0}, f32[1,1]) all-gather-start(f32[2,32]{1,0} %p4)
  %not_a_coll = f32[7]{0} add(f32[7]{0} %a, f32[7]{0} %b)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,1024]") == 16 * 1024 * 2
    assert _shape_bytes("f32[4,4096]") == 4 * 4096 * 4
    assert _shape_bytes("pred[8]") == 8
    assert _shape_bytes("weird[3]") == 0


def test_parse_collectives():
    out = parse_collectives(HLO)
    assert out["all-gather"]["count"] == 2
    assert out["all-gather"]["bytes"] == 16 * 1024 * 2 + (32 * 32 + 1) * 4
    assert out["all-reduce"]["count"] == 1
    assert out["all-reduce"]["bytes"] == 4 * 4096 * 4 * 2  # x2 ring phases
    assert out["reduce-scatter"]["count"] == 1
    assert out["collective-permute"]["bytes"] == 8 * 2
    assert out["total_bytes"] == sum(
        v["bytes"] for k, v in out.items() if isinstance(v, dict))


def _mesh22():
    return jax.make_mesh((2, 2), ("data", "model"),
                         devices=jax.devices() * 4
                         if len(jax.devices()) < 4 else jax.devices()[:4])


def test_legalize_drops_indivisible():
    mesh = jax.sharding.Mesh(
        np.array(jax.devices() * 4)[:4].reshape(2, 2), ("data", "model"))
    # divisible: kept
    spec = legalize_spec(P("data", "model"), (8, 6), mesh)
    assert spec == P("data", "model")
    # indivisible head dim: DROPPED, not shifted onto head_dim
    spec = legalize_spec(P("data", "model", None), (8, 5, 64), mesh)
    assert spec == P("data", None, None)
    # tuple axes (combined size 4)
    spec = legalize_spec(P(("data", "model"),), (6,), mesh)
    assert spec == P(None)
    spec = legalize_spec(P(("data", "model"),), (16,), mesh)
    assert spec == P(("data", "model"))


def test_model_flops_dense_and_moe():
    from repro.configs import load_config
    from repro.configs.base import SHAPES
    cfg = load_config("starcoder2-3b", smoke=True)
    mf_train = model_flops(cfg, SHAPES["train_4k"])
    mf_decode = model_flops(cfg, SHAPES["decode_32k"])
    assert mf_train > 0 and mf_decode > 0
    # train multiplies by 6 and by seq_len x batch tokens
    tokens_train = 4096 * 256
    tokens_decode = 128
    assert mf_train / mf_decode == pytest.approx(
        3 * tokens_train / tokens_decode)
    # MoE counts only active experts
    moe = load_config("deepseek-moe-16b", smoke=True)
    mf_moe = model_flops(moe, SHAPES["decode_32k"])
    assert mf_moe > 0
