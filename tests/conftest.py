"""Shared fixtures + the two-tier test split.

Tiers (documented in ROADMAP.md):

  fast tier   `pytest -m "not slow"` -- everything that finishes in seconds;
              runs on every CI push.
  full tier   plain `pytest` -- adds the @pytest.mark.slow system / dry-run /
              multi-device-subprocess tests; runs on the weekly CI job and
              before releases.

Session-scoped fixtures hold the expensive shared setup (procedural dataset,
controller init + embedding forward) so the system/engine tests don't each
pay for it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (system pipelines, subprocess "
        "multi-device runs); deselect with -m 'not slow'")
    # Internal deprecation shims (repro.core.memory.search /
    # distributed_search) are promoted to ERRORS suite-wide, so migrated
    # callers cannot silently regress onto the legacy API. Modules that
    # deliberately exercise the shims (tests/test_memory.py, the legacy-API
    # suite) scope this back with a filterwarnings mark.
    config.addinivalue_line(
        "filterwarnings",
        "error:repro\\.core\\.memory:DeprecationWarning")


@pytest.fixture(autouse=True)
def _isolate_legacy_shim_warnings():
    """The shims warn once per PROCESS (core/memory._WARNED): without
    isolation, the first legitimate legacy-API test would latch the warning
    for the rest of the run and the error promotion above would never fire
    for a later regressed caller. Restoring the latch around every test
    keeps the promotion live suite-wide."""
    from repro.core import memory as mem
    saved = set(mem._WARNED)
    yield
    mem._WARNED.clear()
    mem._WARNED.update(saved)


@pytest.fixture(scope="session")
def fsl_episode():
    """One deterministic 5-way 5-shot episode of the procedural Omniglot."""
    from repro.data.fsl import EpisodeSampler, OmniglotLike
    ds = OmniglotLike(n_classes=20, image_size=20, seed=0)
    samp = EpisodeSampler(ds, np.arange(20), n_way=5, k_shot=5, n_query=4,
                          seed=0)
    return samp.episode(0)


@pytest.fixture(scope="session")
def conv4_embeddings(fsl_episode):
    """(params, support_embeddings, query_embeddings) of an untrained Conv4."""
    from repro.models.controller import apply_conv4, init_conv4
    params = init_conv4(jax.random.PRNGKey(0), in_ch=1, width=32,
                        embed_dim=24)
    s_emb = apply_conv4(params, jnp.asarray(fsl_episode.support_images))
    q_emb = apply_conv4(params, jnp.asarray(fsl_episode.query_images))
    return params, s_emb, q_emb


@pytest.fixture(scope="session")
def quantized_store():
    """Deterministic quantized (queries, supports) for engine parity tests:
    B=6 queries in [0,4), N=256 supports in [0, levels) at d=48, mtmc cl=8."""
    from repro.core.avss import SearchConfig
    from repro.core.mcam import MCAMConfig
    cfg = SearchConfig("mtmc", cl=8, mode="avss", mcam=MCAMConfig(),
                       use_kernel="ref")
    sv = jax.random.randint(jax.random.PRNGKey(0), (256, 48), 0,
                            cfg.enc.levels)
    qv = jax.random.randint(jax.random.PRNGKey(1), (6, 48), 0, 4)
    return cfg, qv, sv
