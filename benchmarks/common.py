"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def time_us(fn, *args, warmup=1, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


def time_percentiles(fn, *args, warmup=1, iters=5):
    """Like `time_us` but times every call individually and returns
    ({'us', 'p50', 'p95', 'p99'}, out) -- the one shared percentile
    schema benchmark rows attach as their optional 4th element (see
    benchmarks/run.py). 'us' is the mean, directly comparable to
    `time_us` rows."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    arr = np.asarray(ts)
    return {"us": float(arr.mean()),
            "p50": float(np.percentile(arr, 50)),
            "p95": float(np.percentile(arr, 95)),
            "p99": float(np.percentile(arr, 99))}, out


def synthetic_episode(key, n_way, k_shot, n_query, dim, sep=2.2, noise=0.9):
    """Clustered embeddings standing in for controller outputs."""
    kc, ks, kq = jax.random.split(jax.random.PRNGKey(key), 3)
    centers = jax.random.normal(kc, (n_way, dim)) * sep
    s_lab = jnp.repeat(jnp.arange(n_way), k_shot)
    q_lab = jnp.repeat(jnp.arange(n_way), n_query)
    s = centers[s_lab] + noise * jax.random.normal(ks, (len(s_lab), dim))
    q = centers[q_lab] + noise * jax.random.normal(kq, (len(q_lab), dim))
    return s, s_lab, q, q_lab


def quantize_pair(s, q, levels, mode):
    lo, hi = float(s.min()), float(s.max())
    to_int = lambda x, lv: jnp.clip(jnp.round(
        (x - lo) / (hi - lo) * (lv - 1)), 0, lv - 1).astype(jnp.int32)
    return to_int(s, levels), to_int(q, 4 if mode == "avss" else levels)


def search_accuracy(cfg, key=0, n_way=16, k_shot=5, n_query=4, dim=48,
                    sep=1.1, noise=1.0, **kw):
    """Harder default geometry than the tests (sep 1.1 / noise 1.0) so the
    encoding/search-mode accuracy DIFFERENCES are visible."""
    from repro.core import avss as avss_lib
    s, s_lab, q, q_lab = synthetic_episode(key, n_way, k_shot, n_query, dim,
                                           sep=sep, noise=noise, **kw)
    sv, qv = quantize_pair(s, q, cfg.enc.levels, cfg.mode)
    res = avss_lib.search_quantized(qv, sv, cfg)
    pred = avss_lib.predict_1nn(res, s_lab)
    return float((pred == q_lab).mean())


def mean_accuracy(cfg, episodes=5, **kw):
    return float(np.mean([search_accuracy(cfg, key=k, **kw)
                          for k in range(episodes)]))
