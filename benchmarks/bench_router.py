"""Recall-vs-nprobe-vs-latency for the hierarchical router (PR 10).

A class-coherent partitioned store (rows sorted by label before
`shard(n_shards=S)`, the IVF-style layout the router's class-bucket
sketch is built for) is searched at every nprobe in the sweep; each row
reports latency percentiles (one shared schema, `common.time_percentiles`)
plus recall@1 of the routed 1-NN retrieval against the exhaustive
all-shards search. `nprobe=S` must be BYTE-identical to `nprobe=None`
(asserted every run), so the curve's end point IS the baseline.

NOTE: on this CPU container the timings measure XLA CPU (and, past the
fused crossover, the Pallas INTERPRETER); the recall curve and the
routed-vs-exhaustive latency ORDERING are the signal, not absolute
wall-times -- re-measure on a real TPU before using the numbers for
capacity planning (the note is embedded in BENCH_router.json).

    PYTHONPATH=src python -m benchmarks.run --only router      # full sweep
    PYTHONPATH=src python -m benchmarks.bench_router --dry-run # CI gate

--dry-run shrinks the store (N=512, S=8), asserts the routed-parity
contracts, and skips the committed-artifact refresh -- the fast-tier CI
gate that keeps the suite importable and the contracts live.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import quantize_pair, synthetic_episode, \
    time_percentiles
from repro.core.avss import SearchConfig
from repro.engine import MemoryStore, RetrievalEngine, SearchRequest

N, S, B, D, K = 4096, 16, 16, 32, 16
NPROBES = (1, 2, 4, 8, 12, 16)


def _fixture(n, s, n_way, dim, batch):
    """Class-coherent partitioned store + quantized queries: clustered
    episode embeddings, rows SORTED by label so each shard holds few
    classes (the layout that makes a class-centroid sketch selective)."""
    cfg = SearchConfig("mtmc", cl=8, mode="avss", use_kernel="mxu")
    sup, s_lab, qf, _ = synthetic_episode(
        0, n_way, n // n_way, -(-batch // n_way), dim, sep=2.2, noise=0.9)
    sv, qv = quantize_pair(sup, qf, cfg.enc.levels, cfg.mode)
    order = jnp.argsort(jnp.asarray(s_lab), stable=True)
    store = MemoryStore.from_quantized(
        sv[order], jnp.asarray(s_lab)[order].astype(jnp.int32),
        cfg).shard(n_shards=s)
    return cfg, store, qv[:batch]


def _leaves(res):
    return {f: np.asarray(getattr(res, f))
            for f in ("votes", "dist", "indices", "labels")}


def _sweep(n, s, batch, nprobes, iters=5):
    cfg, store, qv = _fixture(n, s, n_way=64, dim=D, batch=batch)
    eng = RetrievalEngine(cfg)
    rows = []

    def f(req):
        return jax.jit(lambda st, q, r=req: eng.search(st, q, r),
                       static_argnames=())

    base_req = SearchRequest(mode="two_phase", k=K)
    stats_ex, res_ex = time_percentiles(f(base_req), store, qv, iters=iters)
    ref = _leaves(res_ex)
    best_ref = ref["indices"][np.arange(qv.shape[0]),
                              np.asarray(res_ex.best())]
    rows.append((f"router/exhaustive_N{n}_S{s}", stats_ex["us"],
                 f"nprobe={s};recall=1.00", stats_ex))

    for p in nprobes:
        if p > s:
            continue
        req = SearchRequest(mode="two_phase", k=K, nprobe=p)
        stats, res = time_percentiles(f(req), store, qv, iters=iters)
        got = _leaves(res)
        if p >= s:   # contract: nprobe=S is the SAME exhaustive program
            for k, v in ref.items():
                np.testing.assert_array_equal(v, got[k], err_msg=k)
        best = got["indices"][np.arange(qv.shape[0]),
                              np.asarray(res.best())]
        recall = float((best == best_ref).mean())
        rows.append((f"router/nprobe{p}_N{n}_S{s}", stats["us"],
                     f"nprobe={p};recall={recall:.2f};"
                     f"speedup_vs_exhaustive="
                     f"{stats_ex['us'] / stats['us']:.1f}x", stats))
    return rows


def run():
    return _sweep(N, S, B, NPROBES)


def dry_run():
    """Fast-tier CI gate: a shrunken sweep plus the routed-parity
    contracts (routed == brute force restricted to the visited shards;
    nprobe=S byte-identical to nprobe=None)."""
    from repro.engine import router as router_lib
    n, s, batch = 512, 8, 6
    rows = _sweep(n, s, batch, (1, 2, s), iters=1)
    cfg, store, qv = _fixture(n, s, n_way=64, dim=D, batch=batch)
    eng = RetrievalEngine(cfg)
    p = 2
    routed = _leaves(eng.search(store, qv,
                                SearchRequest(mode="two_phase", k=K,
                                              nprobe=p)))
    full = _leaves(eng.search(store, qv,
                              SearchRequest(mode="two_phase",
                                            k=store.capacity)))
    scores = router_lib.route_scores(qv, store.sketch_sums,
                                     store.sketch_counts, cfg.enc)
    sids = np.asarray(router_lib.top_shards(scores, p))
    rows_per = store.capacity // s
    for b in range(batch):
        keep = np.isin(full["indices"][b] // rows_per, sids[b])
        for fld in ("dist", "indices", "labels", "votes"):
            np.testing.assert_array_equal(routed[fld][b],
                                          full[fld][b][keep][:K],
                                          err_msg=f"{fld}[{b}]")
    for name, us, derived, _ in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"# dry-run OK: routed parity held at N={n} S={s} nprobe={p}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="small-N parity gate (CI fast tier); no artifacts")
    if ap.parse_args().dry_run:
        dry_run()
    else:
        for name, us, derived, _ in run():
            print(f"{name},{us:.1f},{derived}")
