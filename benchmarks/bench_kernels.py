"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference semantics +
the two-phase shortlist recall curve. NOTE: wall-times on this CPU container
measure the INTERPRETER, not TPU performance -- the TPU-side analysis lives
in the roofline (benchmarks/roofline.py); these rows track relative costs and
correctness at benchmark scale.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_us
from repro.core import avss as avss_lib
from repro.core.avss import SearchConfig
from repro.core.mcam import MCAMConfig
from repro.kernels import ops


def run():
    rows = []
    cfg = SearchConfig("mtmc", cl=8, mode="avss",
                       mcam=MCAMConfig(), use_kernel="ref")
    enc = cfg.enc
    key = jax.random.PRNGKey(0)
    N, B, d = 512, 8, 48
    sv = jax.random.randint(key, (N, d), 0, enc.levels)
    qv = jax.random.randint(jax.random.PRNGKey(1), (B, d), 0, 4)

    # reference full search
    f_ref = jax.jit(lambda q, s: avss_lib.search_quantized(q, s, cfg)["votes"])
    us, votes_ref = time_us(f_ref, qv, sv, iters=2)
    rows.append((f"kernel/ref_full_N{N}", us, "backend=jnp"))

    # pallas full search (interpret mode on CPU)
    cfg_k = SearchConfig("mtmc", cl=8, mode="avss",
                         mcam=MCAMConfig(), use_kernel="pallas")
    f_pal = jax.jit(lambda q, s: avss_lib.search_quantized(q, s, cfg_k)["votes"])
    us, votes_pal = time_us(f_pal, qv, sv, iters=2)
    np.testing.assert_allclose(np.asarray(votes_ref), np.asarray(votes_pal),
                               rtol=1e-5)
    rows.append((f"kernel/pallas_full_N{N}", us, "backend=pallas-interpret"))

    # MXU LUT distance
    f_mxu = jax.jit(lambda q, s: ops.avss_ideal_dist(q, s, enc))
    us, _ = time_us(f_mxu, qv, sv, iters=3)
    rows.append((f"kernel/mxu_lut_dist_N{N}", us,
                 f"inner_dim={4*d};dtype=bf16"))

    # two-phase recall@k
    full = avss_lib.search_quantized(qv, sv, cfg)
    full_best = np.asarray(jnp.argmax(
        full["votes"] - 1e-6 * full["dist"], -1))
    recalls = []
    for k in (16, 32, 64, 128):
        tp = ops.two_phase_search(qv, sv, cfg, k=k)
        sc = np.asarray(tp["votes"]) - 1e-6 * np.asarray(tp["dist"])
        tp_best = np.asarray(tp["indices"])[np.arange(B), sc.argmax(1)]
        recalls.append((k, float((full_best == tp_best).mean())))
    rows.append(("kernel/two_phase_recall", 0.0,
                 ";".join(f"k{k}={r:.2f}" for k, r in recalls)))
    return rows
