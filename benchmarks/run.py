# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig9,...]

Suites:
  table1   encoding rules (bench_encodings)
  fig3_5   mismatch-level distributions B4E vs MTMC (bench_mismatch)
  table2   SVSS vs AVSS accuracy + throughput (bench_avss)
  fig9     energy-accuracy Pareto fronts (bench_pareto)
  kernel   Pallas kernels + two-phase recall (bench_kernels)
  engine   retrieval engine: full vs two-phase vs sharded vs store-based
           unified search, plus the streaming-write and large-N ideal
           serving rows (bench_engine)
  engine_sharded  multi-device sharded scaling (search, shard-local
           streaming writes, AND the per-shard shortlist dense-vs-fused
           sweep) on a forced 8-device host mesh (subprocess, like
           tests/test_distributed.py); writes
           results/bench_engine_sharded.json (CI artifact)
  router   hierarchical-routing sweep: recall@1 + latency percentiles per
           nprobe on a class-coherent partitioned store (bench_router);
           refreshes the committed repo-root BENCH_router.json
  hat      hardware-aware training step timings (episodic meta-train step
           through the engine's differentiable MCAM forward vs the plain
           pretrain step) + the per-encoding engine.search cost sweep
           (mtmc/b4e/b4we/sre) -- bench_hat
  roofline dry-run derived roofline terms (benchmarks.roofline; needs the
           dryrun sweep artifacts under results/dryrun)

Every run also consolidates the rows of ALL executed suites into
results/bench_summary.json (uploaded as a CI artifact by the weekly full
job), so the perf trajectory is tracked PR-over-PR in one file.

results/ is NOT committed, so any run that refreshes the `engine` suite
also emits the committed repo-root `BENCH_shortlist.json` -- the
dense-vs-fused shortlist rows at the acceptance shape (N=4096) next to
the pinned pre-rework baseline -- making the kernel's crossover claim
checkable from the repo alone.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

SUITES = {
    "table1": "benchmarks.bench_encodings",
    "fig3_5": "benchmarks.bench_mismatch",
    "table2": "benchmarks.bench_avss",
    "fig9": "benchmarks.bench_pareto",
    "kernel": "benchmarks.bench_kernels",
    "engine": "benchmarks.bench_engine",
    "engine_sharded": "benchmarks.bench_engine_sharded",
    "router": "benchmarks.bench_router",
    "hat": "benchmarks.bench_hat",
    "roofline": "benchmarks.roofline",
}

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SUMMARY_PATH = os.path.join(ROOT, "results", "bench_summary.json")
SHORTLIST_PATH = os.path.join(ROOT, "BENCH_shortlist.json")
ROUTER_PATH = os.path.join(ROOT, "BENCH_router.json")

# The large-N ideal rows as measured BEFORE the shortlist kernel rework
# (PR 5, same CPU pallas-interpret mode): the fused kernel's O(k*(k+tile_n))
# per-step extraction loop left it at 0.1x of the dense path it replaced.
# Pinned here so BENCH_shortlist.json always shows the trajectory.
SHORTLIST_BASELINE = {
    "pr": 5,
    "engine/ideal_dense_N4096": {"us_per_call": 4500.0},
    "engine/ideal_fused_N4096": {"us_per_call": 86000.0,
                                 "speedup_vs_dense": 0.05},
}


def _emit_shortlist_bench(engine_rows: list[dict]) -> bool:
    """Refresh the committed repo-root BENCH_shortlist.json from the engine
    suite's large-N ideal rows (dense vs fused, before/after)."""
    after = {r["name"]: r for r in engine_rows
             if r["name"].startswith("engine/ideal_")}
    if len(after) < 2:
        return False
    with open(SHORTLIST_PATH, "w") as f:
        json.dump({"generated_by": "benchmarks.run --only engine",
                   "measurement": "cpu pallas-interpret (same mode as the "
                                  "pinned PR5 baseline)",
                   "before": SHORTLIST_BASELINE,
                   "after": after}, f, indent=1)
    return True


def _emit_router_bench(router_rows: list[dict]) -> bool:
    """Refresh the committed repo-root BENCH_router.json from the router
    suite: the recall-vs-nprobe-vs-latency curve (percentiles included),
    so the routing claim is checkable from the repo alone."""
    if not router_rows:
        return False
    with open(ROUTER_PATH, "w") as f:
        json.dump({"generated_by": "benchmarks.run --only router",
                   "measurement": "cpu xla / pallas-interpret past the "
                                  "fused crossover -- recall curve and "
                                  "routed-vs-exhaustive ordering are the "
                                  "signal; re-measure on TPU for absolute "
                                  "latencies",
                   "rows": router_rows}, f, indent=1)
    return True


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)
    print("name,us_per_call,derived")
    failed = []
    summary = {}
    import importlib
    for key, modname in SUITES.items():
        if key not in only:
            continue
        try:
            mod = importlib.import_module(modname)
            suite_rows = []
            # rows are (name, us, derived) or (name, us, derived, stats)
            # where stats is common.time_percentiles' shared schema
            for row in mod.run():
                name, us, derived = row[:3]
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
                entry = {"name": name, "us_per_call": us,
                         "derived": derived}
                if len(row) > 3 and row[3]:
                    entry["percentiles"] = row[3]
                suite_rows.append(entry)
            summary[key] = suite_rows
        except Exception as e:  # keep the harness going; report at the end
            failed.append((key, repr(e)))
            print(f"{key}/ERROR,0.0,{e!r}")
    # merge into any existing summary: CI invokes the harness once per
    # suite, and the artifact should accumulate them all
    merged = {}
    try:
        with open(SUMMARY_PATH) as f:
            prev = json.load(f)
        merged = dict(prev.get("suites", {}))
    except (OSError, ValueError):
        pass
    merged.update(summary)
    # fold the contract guard's latest pass/fail counts into the artifact
    # (written by `python -m repro.analysis run`; absent = not run here)
    contracts = None
    try:
        with open(os.path.join(ROOT, "results",
                               "contract_report.json")) as f:
            contracts = json.load(f)["summary"]
    except (OSError, ValueError, KeyError):
        pass
    # ... and the resource oracle's route counts + total static FLOPs
    # (written by `python -m repro.analysis cost`; absent = not run here)
    resources = None
    try:
        with open(os.path.join(ROOT, "results",
                               "resource_report.json")) as f:
            resources = json.load(f)["summary"]
    except (OSError, ValueError, KeyError):
        pass
    os.makedirs(os.path.dirname(SUMMARY_PATH), exist_ok=True)
    with open(SUMMARY_PATH, "w") as f:
        json.dump({"generated_by": "benchmarks.run",
                   "last_run": sorted(only & set(SUITES)),
                   "failed": failed, "contracts": contracts,
                   "resources": resources,
                   "suites": merged}, f, indent=1)
    print(f"# wrote {os.path.relpath(SUMMARY_PATH, ROOT)} "
          f"({sum(len(v) for v in merged.values())} rows, "
          f"{len(merged)} suite(s))")
    if "engine" in summary and _emit_shortlist_bench(summary["engine"]):
        print(f"# wrote {os.path.relpath(SHORTLIST_PATH, ROOT)} "
              f"(dense-vs-fused shortlist trajectory)")
    if "router" in summary and _emit_router_bench(summary["router"]):
        print(f"# wrote {os.path.relpath(ROUTER_PATH, ROOT)} "
              f"(recall-vs-nprobe routing curve)")
    if failed:
        print(f"# {len(failed)} suite(s) failed: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
