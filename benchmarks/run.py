# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig9,...]

Suites:
  table1   encoding rules (bench_encodings)
  fig3_5   mismatch-level distributions B4E vs MTMC (bench_mismatch)
  table2   SVSS vs AVSS accuracy + throughput (bench_avss)
  fig9     energy-accuracy Pareto fronts (bench_pareto)
  kernel   Pallas kernels + two-phase recall (bench_kernels)
  engine   retrieval engine: full vs two-phase vs sharded vs store-based
           unified search (bench_engine)
  engine_sharded  multi-device sharded scaling on a forced 8-device host
           mesh (subprocess, like tests/test_distributed.py); writes
           results/bench_engine_sharded.json (CI artifact)
  roofline dry-run derived roofline terms (benchmarks.roofline; needs the
           dryrun sweep artifacts under results/dryrun)
"""

from __future__ import annotations

import argparse
import sys

SUITES = {
    "table1": "benchmarks.bench_encodings",
    "fig3_5": "benchmarks.bench_mismatch",
    "table2": "benchmarks.bench_avss",
    "fig9": "benchmarks.bench_pareto",
    "kernel": "benchmarks.bench_kernels",
    "engine": "benchmarks.bench_engine",
    "engine_sharded": "benchmarks.bench_engine_sharded",
    "roofline": "benchmarks.roofline",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)
    print("name,us_per_call,derived")
    failed = []
    import importlib
    for key, modname in SUITES.items():
        if key not in only:
            continue
        try:
            mod = importlib.import_module(modname)
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:  # keep the harness going; report at the end
            failed.append((key, repr(e)))
            print(f"{key}/ERROR,0.0,{e!r}")
    if failed:
        print(f"# {len(failed)} suite(s) failed: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
