"""Paper Table 2: SVSS vs AVSS -- accuracy and throughput.

Throughput comes from the analytic device model (iterations x the measured
block rate of [14], Sec. 4.3); accuracy from the noisy MCAM simulator on
clustered synthetic episodes of the paper's Omniglot geometry (d=48, CL=32)
and CUB geometry (d=480, CL=25).
"""

from __future__ import annotations

import time

from benchmarks.common import mean_accuracy
from repro.core import costmodel
from repro.core.avss import SearchConfig
from repro.core.mcam import MCAMConfig


def run():
    rows = []
    mcam = MCAMConfig(sigma_device=0.1, sigma_read=0.04)
    for tag, d, cl, dim_kw in [("omniglot", 48, 32, dict(dim=48)),
                               ("cub", 480, 25, dict(dim=480, n_way=10,
                                                     episodes=2))]:
        episodes = dim_kw.pop("episodes", 3)
        accs, thr = {}, {}
        for mode in ("svss", "avss"):
            cfg = SearchConfig("mtmc", cl=cl, mode=mode, mcam=mcam,
                               use_kernel="ref")
            t0 = time.perf_counter()
            accs[mode] = mean_accuracy(cfg, episodes=episodes, **dim_kw)
            dt = (time.perf_counter() - t0) * 1e6 / episodes
            thr[mode] = costmodel.throughput_searches_per_s(d, cfg.enc, mode)
            rows.append((f"table2/{tag}_{mode}", dt,
                         f"acc={accs[mode]:.3f};"
                         f"searches_per_s={thr[mode]:.1f}"))
        speedup = thr["avss"] / thr["svss"]
        rows.append((f"table2/{tag}_speedup", 0.0,
                     f"avss_speedup={speedup:.0f}x;"
                     f"acc_drop={accs['svss'] - accs['avss']:+.3f}"))
    return rows
