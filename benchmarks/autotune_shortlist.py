"""Autotune the fused shortlist: sweep (tile_b, tile_n, k_pad), find the
dense-vs-fused crossover, and emit the measured `fused_min_rows` setting.

    PYTHONPATH=src python -m benchmarks.autotune_shortlist [--dry-run]

For each support count N the harness times the dense reference (the full
(B, N) distance matrix + lax.top_k -- the exact computation the engine's
`ideal` route runs below the fused threshold) against the fused Pallas
shortlist (kernels/shortlist.py) over a grid of tiling knobs, always with
the store's bit-packed projection operand (MemoryStore.proj_packed -- the
configuration the engine actually serves). Every timed variant is also
checked bit-exact against the dense reference, so a tile-shape regression
fails the run (the fast CI job runs `--dry-run` on every push).

Before anything is timed, every explicit (tile_b, tile_n, k_pad) config is
priced by the symbolic VMEM model (repro/analysis/vmem.py) against the
16 MiB TPU budget; over-budget configs are skipped up front (recorded under
`skipped_configs` in the output JSON) so a TPU autotune session cannot OOM
mid-sweep.

The crossover -- the smallest swept N whose best fused config is at least
as fast as dense -- is written to `results/autotune_shortlist.json` as
`fused_min_rows`. Applying it needs no code change: the knob is already
plumbed end to end (`RetrievalEngine(fused_min_rows=...)`,
`SearchRequest.fused_min_rows`, `serve --retrieval-fused-min-rows`).

Measurement mode note: on this CPU container the fused rows time the
Pallas INTERPRETER (interpret=True is the kernel's CPU default), which is
also how the committed BENCH_shortlist.json baseline was measured; re-run
on real TPU hardware to tune for HBM. k_pad only affects the bitonic
network path (compiled TPU); under interpret the native path ignores it.
"""

from __future__ import annotations

import argparse
import itertools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_us
from repro.analysis import vmem as vmem_lib
from repro.core.encodings import make_encoding
from repro.kernels import ops as kernel_ops
from repro.kernels.shortlist import lut_shortlist_pallas

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "results", "autotune_shortlist.json")

FULL = dict(ns=(1024, 2048, 4096, 8192), tile_bs=(8, 16),
            tile_ns=(256, 512, 1024), k_pads=(128, 256),
            B=16, D=48, k=64, iters=3)
DRY = dict(ns=(512,), tile_bs=(8,), tile_ns=(256,), k_pads=(128,),
           B=4, D=16, k=16, iters=1)


def _dense(q1h, proj, k):
    dist = q1h.astype(jnp.float32) @ proj.astype(jnp.float32).T
    neg, idx = jax.lax.top_k(-dist, k)
    return -neg, idx


def plan_configs(tile_bs, tile_ns, k_pads, *, k, width, pack_bits,
                 q_dtype_bytes=2):
    """Static VMEM gate over the sweep grid (analysis/vmem.py): every
    explicit (tile_b, tile_n, k_pad) config is priced against the TPU
    budget BEFORE anything lowers, so an oversized tile can never OOM a
    TPU autotune session. Returns (accepted configs, skipped records);
    ("default",) -- the kernel's adaptive tiling -- is always accepted."""
    configs = [("default",)]
    skipped = []
    for tb, tn, kpd in itertools.product(tile_bs, tile_ns, k_pads):
        chk = vmem_lib.validate_config(
            tb, tn, k, width=width, k_pad=kpd, pack_bits=pack_bits,
            q_dtype_bytes=q_dtype_bytes, use_network=True)
        if chk.ok:
            configs.append((tb, tn, kpd))
        else:
            skipped.append({"config": f"tb={tb},tn={tn},kp={kpd}",
                            "vmem_bytes": chk.estimate.total_bytes,
                            "budget_bytes": chk.budget_bytes,
                            "reason": chk.reason})
    return configs, skipped


def sweep(ns, tile_bs, tile_ns, k_pads, B, D, k, iters):
    enc = make_encoding("mtmc", 8)
    bits = kernel_ops.projection_pack_bits(enc, jnp.bfloat16)
    # the gate models the compiled TPU lowering (bf16 query operand,
    # bitonic network padding) -- the only target with a VMEM budget
    configs, skipped = plan_configs(tile_bs, tile_ns, k_pads, k=k,
                                    width=4 * D, pack_bits=bits)
    for s in skipped:
        print(f"# skip {s['config']}: {s['reason']}")
    rows, crossover = [], None
    for n in ns:
        sv = jax.random.randint(jax.random.PRNGKey(n), (n, D), 0, enc.levels)
        qv = jax.random.randint(jax.random.PRNGKey(n + 1), (B, D), 0, 4)
        q1h = kernel_ops.query_onehot(qv, jnp.bfloat16)
        proj = kernel_ops.support_projection(sv, enc, jnp.bfloat16)
        packed = kernel_ops.pack_projection(proj, enc)
        us_dense, ref = time_us(
            jax.jit(lambda q, p: _dense(q, p, k)), q1h, proj, iters=iters)
        rows.append({"n": n, "config": "dense", "us": us_dense})
        print(f"N={n:5d} dense                         {us_dense:9.0f}us")
        best = None
        # ("default",) = the kernel's adaptive interpret tiling -- what an
        # untuned engine run actually executes
        for cfgt in configs:
            kw = {} if cfgt == ("default",) else dict(
                tile_b=cfgt[0], tile_n=cfgt[1], k_pad=cfgt[2])
            f = jax.jit(lambda q, p, kw=kw: lut_shortlist_pallas(
                q, None, k, packed=p, pack_bits=bits, **kw))
            us, out = time_us(f, q1h, packed, iters=iters)
            for a, b in zip(out, ref):   # bit-parity gate on every config
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"fused != dense at N={n}, config={cfgt}")
            label = "default" if cfgt == ("default",) else \
                f"tb={cfgt[0]},tn={cfgt[1]},kp={cfgt[2]}"
            rows.append({"n": n, "config": label, "us": us,
                         "speedup_vs_dense": us_dense / us})
            print(f"N={n:5d} fused {label:23s} {us:9.0f}us "
                  f"({us_dense / us:.2f}x dense)")
            if best is None or us < best[1]:
                best = (label, us)
        if crossover is None and best[1] <= us_dense:
            crossover = n
    return rows, crossover, skipped


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sweep (CI parity/regression gate)")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    params = DRY if args.dry_run else FULL
    rows, crossover, skipped = sweep(**params)
    out = {
        "generated_by": "benchmarks.autotune_shortlist"
                        + (" --dry-run" if args.dry_run else ""),
        "backend": jax.default_backend(),
        "measurement": "pallas-interpret"
                       if jax.default_backend() == "cpu" else "compiled",
        "params": {k: v for k, v in params.items()},
        "fused_min_rows": crossover,
        "skipped_configs": skipped,
        "rows": rows,
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {os.path.relpath(args.out, ROOT)}")
    if crossover is not None:
        print(f"# measured dense-vs-fused crossover: N={crossover} -- apply "
              f"with --retrieval-fused-min-rows {crossover} (or "
              f"RetrievalEngine(fused_min_rows={crossover}))")
    else:
        print("# fused never beat dense in this sweep; keep the dense path "
              "(fused_min_rows large) or re-run on real hardware")


if __name__ == "__main__":
    main()
