"""Multi-device sharded-engine scaling, measured for real in a subprocess.

The in-process `engine` suite runs its sharded row on however many devices
the host exposes (1 on a plain CPU run). This suite forces an 8-device host
mesh the way tests/test_distributed.py does -- XLA_FLAGS must precede jax
init, so it MUST be a subprocess -- and sweeps shard counts over a fixed
store so the sharded scaling shape lands in the perf trajectory, plus the
per-shard shortlist dense-vs-fused comparison (steered purely by the
SearchRequest.fused_min_rows knob, bit-parity asserted). Results
are printed as harness rows AND written to results/bench_engine_sharded.json
(uploaded as a CI artifact by the weekly full job).

    PYTHONPATH=src python -m benchmarks.run --only engine_sharded
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "results", "bench_engine_sharded.json")
N_DEVICES = 8

_WORKER = """
    import json, time
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.avss import SearchConfig
    from repro.core.mcam import MCAMConfig
    from repro.core.memory import MemoryConfig
    from repro.engine import MemoryStore, RetrievalEngine, SearchRequest

    N, B, D, K = 4096, 16, 48, 64
    cfg = SearchConfig("mtmc", cl=8, mode="avss", mcam=MCAMConfig(),
                       use_kernel="ref")
    sv = jax.random.randint(jax.random.PRNGKey(0), (N, D), 0, cfg.enc.levels)
    qv = jax.random.randint(jax.random.PRNGKey(1), (B, D), 0, 4)
    labels = jnp.arange(N, dtype=jnp.int32) % 512
    store = MemoryStore.from_quantized(sv, labels, cfg)
    eng = RetrievalEngine(cfg, backend="ref")
    req = SearchRequest(mode="two_phase", k=K)

    def time_us(f, *args, iters=3):
        f(*args)[0].block_until_ready()          # compile + warm
        t0 = time.perf_counter()
        for _ in range(iters):
            out = f(*args)
            out[0].block_until_ready()
        return (time.perf_counter() - t0) / iters * 1e6, out

    base = jax.jit(lambda st, q: (eng.search(st, q, req).votes,))
    us1, (ref_votes,) = time_us(base, store, qv)
    records = [{"name": "engine_sharded/two_phase_k%d_dev1" % K,
                "us_per_call": us1, "shards": 1,
                "qps": B / us1 * 1e6}]
    for n_dev in (2, 4, 8):
        mesh = jax.make_mesh((n_dev,), ("data",))
        sstore = store.shard(mesh, ("data",))
        with mesh:
            f = jax.jit(lambda st, q: (eng.search(st, q, req).votes,))
            us, (votes,) = time_us(f, sstore, qv)
        np.testing.assert_array_equal(np.asarray(ref_votes),
                                      np.asarray(votes))
        records.append({"name": "engine_sharded/two_phase_k%d_dev%d"
                                % (K, n_dev),
                        "us_per_call": us, "shards": n_dev,
                        "qps": B / us * 1e6,
                        "speedup_vs_1dev": us1 / us})

    # sharded per-shard shortlist: dense local matmul vs the fused Pallas
    # kernel (ISSUE 4 tentpole). The SearchRequest.fused_min_rows override
    # steers the dispatch without code change; bit-parity is asserted
    # against the unsharded ideal reference either way. NOTE: on this CPU
    # container the fused rows measure the Pallas INTERPRETER -- the
    # dense-vs-fused *crossover* must be measured on real TPU HBM; these
    # rows track that both routes stay wired and bit-identical.
    ideal_ref = jax.jit(lambda st, q: (eng.search(
        st, q, SearchRequest(mode="ideal", k=K)).dist,))
    _, (ref_dist,) = time_us(ideal_ref, store, qv)
    for n_dev in (2, 8):
        mesh = jax.make_mesh((n_dev,), ("data",))
        sstore = store.shard(mesh, ("data",))
        for tag, fmr in (("dense", 1 << 30), ("fused", 1)):
            req = SearchRequest(mode="ideal", k=K, backend="mxu",
                                fused_min_rows=fmr)
            with mesh:
                f = jax.jit(lambda st, q, r=req: (eng.search(st, q, r).dist,))
                us, (dist,) = time_us(f, sstore, qv)
            np.testing.assert_array_equal(np.asarray(ref_dist),
                                          np.asarray(dist))
            records.append({"name": "engine_sharded/ideal_%s_k%d_dev%d"
                                    % (tag, K, n_dev),
                            "us_per_call": us, "shards": n_dev,
                            "shortlist": tag, "qps": B / us * 1e6})
    mesh = jax.make_mesh((8,), ("data",))
    sstore = store.shard(mesh, ("data",))
    for tag, fmr in (("dense", 1 << 30), ("fused", 1)):
        req = SearchRequest(mode="two_phase", k=K, backend="mxu",
                            fused_min_rows=fmr)
        with mesh:
            f = jax.jit(lambda st, q, r=req: (eng.search(st, q, r).votes,))
            us, (votes,) = time_us(f, sstore, qv)
        np.testing.assert_array_equal(np.asarray(ref_votes),
                                      np.asarray(votes))
        records.append({"name": "engine_sharded/two_phase_%s_k%d_dev8"
                                % (tag, K),
                        "us_per_call": us, "shards": 8,
                        "shortlist": tag, "qps": B / us * 1e6})

    # streaming (shard-local) writes: program a W-row batch into the ring;
    # the write-through keeps programming local to each shard, so per-batch
    # time should stay flat (no cross-device scatter) as shards grow
    W = 256
    mcfg = MemoryConfig(capacity=N, dim=D, search=cfg)
    wvecs = jax.random.normal(jax.random.PRNGKey(2), (W, D))
    wlabs = jnp.arange(W, dtype=jnp.int32)
    base = MemoryStore.create(mcfg).calibrate(wvecs)
    fw = jax.jit(lambda st, v, l: (st.write(v, l).values,))
    usw1, (ref_vals,) = time_us(fw, base, wvecs, wlabs)
    records.append({"name": "engine_sharded/write_scatter_b%d_dev1" % W,
                    "us_per_call": usw1, "shards": 1,
                    "rows_per_s": W / usw1 * 1e6})
    for n_dev in (2, 4, 8):
        mesh = jax.make_mesh((n_dev,), ("data",))
        sbase = base.shard(mesh, ("data",))
        with mesh:
            fws = jax.jit(lambda st, v, l: (st.write(v, l).values,))
            usw, (vals,) = time_us(fws, sbase, wvecs, wlabs)
        np.testing.assert_array_equal(np.asarray(ref_vals),
                                      np.asarray(vals))
        records.append({"name": "engine_sharded/write_stream_b%d_dev%d"
                                % (W, n_dev),
                        "us_per_call": usw, "shards": n_dev,
                        "rows_per_s": W / usw * 1e6,
                        "speedup_vs_1dev": usw1 / usw})
    print("JSON::" + json.dumps({
        "suite": "engine_sharded", "N": N, "B": B, "D": D, "k": K,
        "devices": len(jax.devices()), "backend": "ref",
        "note": "CPU host mesh; interpreter timings -- scaling SHAPE is "
                "the signal, parity is asserted bit-exact",
        "rows": records}))
"""


def run():
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEVICES}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(_WORKER)],
                          capture_output=True, text=True, timeout=1200,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded bench subprocess failed:\n"
                           f"{proc.stderr[-2000:]}")
    payload = None
    for line in proc.stdout.splitlines():
        if line.startswith("JSON::"):
            payload = json.loads(line[len("JSON::"):])
    assert payload is not None, proc.stdout[-2000:]
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    rows = []
    for r in payload["rows"]:
        rate = (f"qps={r['qps']:.0f}" if "qps" in r
                else f"rows_per_s={r['rows_per_s']:.0f}")
        derived = f"{rate};shards={r['shards']}"
        if "shortlist" in r:
            derived += f";shortlist={r['shortlist']}"
        if "speedup_vs_1dev" in r:
            derived += f";speedup_vs_1dev={r['speedup_vs_1dev']:.2f}x"
        rows.append((r["name"], r["us_per_call"], derived))
    rows.append(("engine_sharded/artifact", 0.0,
                 os.path.relpath(OUT_PATH, ROOT)))
    return rows
