"""HAT trainer + encoding-sweep benchmark rows (ISSUE 5 satellite).

Two row families, merged into results/bench_summary.json by benchmarks.run:

* hat/meta_train_step -- wall time of one jitted episodic meta-train step
  through the engine's differentiable MCAM forward (the stage-2 inner
  loop of `launch/train.py --hat`), plus the stage-1 pretrain step as a
  baseline for the hardware-simulation overhead.
* encoding_sweep/* -- `engine.search` cost per encoding (mtmc / b4e /
  b4we / sre) on the same store geometry: what the paper's Table 1
  encoding choice costs at serve time (two-phase, mxu backend), with the
  word-line iteration count in the derived column.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_us
from repro.core.avss import SearchConfig, search_iterations
from repro.core.hat import HATConfig
from repro.engine import MemoryStore, RetrievalEngine, SearchRequest
from repro.launch.steps import make_hat_train_steps
from repro.optim import adamw


def _hat_step_rows():
    dim, n_way, k_shot, n_query = 24, 6, 3, 4
    hat = HATConfig(search=SearchConfig("mtmc", cl=8, mode="avss",
                                        use_kernel="ref"))
    apply_fn = lambda p, x: jax.nn.relu(x @ p["w"])
    opt = adamw(1e-3)
    pre_step, meta_step, _ = make_hat_train_steps(apply_fn, hat, opt,
                                                  n_way=n_way)
    params = {"backbone": {"w": jax.random.normal(jax.random.PRNGKey(0),
                                                  (32, dim)) * 0.1},
              "head": {"w": jnp.zeros((dim, n_way)),
                       "b": jnp.zeros((n_way,))}}
    s_lab = jnp.repeat(jnp.arange(n_way), k_shot)
    q_lab = jnp.repeat(jnp.arange(n_way), n_query)
    ep = {"support_images": jax.random.normal(
              jax.random.PRNGKey(1), (len(s_lab), 32)),
          "support_labels": s_lab,
          "query_images": jax.random.normal(
              jax.random.PRNGKey(2), (len(q_lab), 32)),
          "query_labels": q_lab}
    opt_state = opt.init(params)
    us_meta, _ = time_us(
        lambda: meta_step(params, opt_state, ep, jax.random.PRNGKey(3)))
    batch = {"image": ep["support_images"], "label": s_lab}
    us_pre, _ = time_us(lambda: pre_step(params, opt_state, batch))
    geo = f"nway={n_way};kshot={k_shot};nq={n_query};dim={dim};cl=8"
    return [("hat/meta_train_step", us_meta, geo),
            ("hat/pretrain_step", us_pre, geo)]


def _encoding_sweep_rows():
    rows = []
    n, d, b, k = 512, 48, 8, 32
    for name, cl in [("mtmc", 8), ("b4e", 3), ("b4we", 2), ("sre", 4)]:
        cfg = SearchConfig(name, cl=cl, mode="avss", use_kernel="ref")
        sv = jax.random.randint(jax.random.PRNGKey(0), (n, d), 0,
                                cfg.enc.levels)
        qv = jax.random.randint(jax.random.PRNGKey(1), (b, d), 0, 4)
        store = MemoryStore.from_quantized(
            sv, jnp.arange(n, dtype=jnp.int32) % 17, cfg)
        eng = RetrievalEngine(cfg, backend="mxu")
        req = SearchRequest(mode="two_phase", k=k)
        fn = jax.jit(lambda q, st=store, e=eng, r=req: e.search(st, q, r))
        us, _ = time_us(fn, qv)
        iters = search_iterations(d, cfg.enc, "avss")
        rows.append((f"encoding_sweep/{name}_cl{cl}_two_phase", us,
                     f"N={n};d={d};B={b};k={k};levels={cfg.enc.levels};"
                     f"words={cfg.enc.length};iterations={iters}"))
    return rows


def run():
    return _hat_step_rows() + _encoding_sweep_rows()
