"""Roofline table from the dry-run sweep artifacts (results/dryrun/*.json).

For every (arch x shape x mesh) cell: the three terms
    compute_s    = HLO_FLOPs/device / 197 TFLOP/s        (bf16, v5e)
    memory_s     = HLO_bytes/device / 819 GB/s
    collective_s = collective_bytes/device / 50 GB/s
(trip-count-corrected, see repro/launch/dryrun.py), the dominant term, the
MODEL_FLOPS/HLO_FLOPs usefulness ratio, and per-device state bytes.
"""

from __future__ import annotations

import glob
import json
import os


def load_records(results_dir="results/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        try:
            recs.append(json.load(open(f)))
        except Exception:
            pass
    return recs


def table(recs):
    lines = ["| arch | shape | mesh | compute_s | memory_s | collective_s |"
             " dominant | useful | state GB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | -"
                         f" | - | {r['reason']} | - | - |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                         f" {r['status']} | | | | | |")
            continue
        rf = r["roofline"]
        ur = r.get("useful_flops_ratio")
        ur_s = f"{ur:.3f}" if ur is not None else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
            f" {rf['compute_s']:.4g} | {rf['memory_s']:.4g} |"
            f" {rf['collective_s']:.4g} | {rf['dominant']} | {ur_s} |"
            f" {r['state_bytes_per_device']/2**30:.2f} |")
    return "\n".join(lines)


def run():
    recs = load_records()
    rows = []
    for r in recs:
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        rows.append((f"roofline/{r['arch']}_{r['shape']}_{r['mesh']}",
                     rf["bound_s"] * 1e6,
                     f"dom={rf['dominant']};compute={rf['compute_s']:.3g};"
                     f"mem={rf['memory_s']:.3g};coll={rf['collective_s']:.3g};"
                     f"useful={r.get('useful_flops_ratio') or 0:.3f}"))
    if not rows:
        rows.append(("roofline/none", 0.0,
                     "run benchmarks.dryrun_sweep first"))
    return rows


if __name__ == "__main__":
    print(table(load_records()))
