"""Render dry-run/perf artifacts into EXPERIMENTS.md placeholder markers."""

from __future__ import annotations

import glob
import json
import os

MD = "EXPERIMENTS.md"


def _load(path):
    try:
        return json.load(open(path))
    except Exception:
        return None


def roofline_rows(pattern):
    rows = []
    for f in sorted(glob.glob(pattern)):
        r = _load(f)
        if not r:
            continue
        if "_mcam" in os.path.basename(f):
            r = dict(r, shape=r["shape"] + " +MCAM")
        rows.append(r)
    return rows


def render_table(recs):
    lines = ["| arch | shape | compute_s | memory_s | collective_s |"
             " dominant | useful | state GB/dev | peak-temp GB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs = sorted(recs, key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — |"
                         f" {r['reason']} | — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']} |"
                         " | | | | | |")
            continue
        rf = r["roofline"]
        ur = r.get("useful_flops_ratio")
        mem = r.get("memory_analysis", {})
        temp = mem.get("temp_size_in_bytes", 0) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4g} |"
            f" {rf['memory_s']:.4g} | {rf['collective_s']:.4g} |"
            f" **{rf['dominant']}** |"
            f" {ur:.3f} |" + f" {r['state_bytes_per_device']/2**30:.2f} |"
            f" {temp:.1f} |")
    return "\n".join(lines)


def render_analysis(recs):
    out = []
    fixes = {
        "compute": "more MXU-efficient layout (larger microbatch, fused "
                   "einsums) or simply accept: compute-bound is the goal",
        "memory": "cut HBM passes: unchunk short-seq attention, selective "
                  "remat of FFN blocks, bf16 end-to-end residual stream",
        "collective": "reduce FSDP regather traffic (larger microbatch, "
                      "weight-gather hoisting), overlap with compute "
                      "(latency-hiding scheduler), int8 cross-pod grads",
    }
    for r in recs:
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        mf = r.get("model_flops_total", 0)
        out.append(
            f"* **{r['arch']} / {r['shape']}** — dominant: {rf['dominant']}"
            f" ({rf['bound_s']:.3g}s vs compute {rf['compute_s']:.3g}s);"
            f" MODEL_FLOPS={mf:.3g},"
            f" useful ratio {r.get('useful_flops_ratio') or 0:.3f}."
            f" To move it: {fixes[rf['dominant']]}.")
    return "\n".join(out)


def replace_block(text, marker, content):
    tag = f"<!-- {marker} -->"
    if tag not in text:
        return text
    return text.replace(tag, content)


def main():
    text = open(MD).read()
    single = [r for r in roofline_rows("results/dryrun/*_single*.json")]
    multi = [r for r in roofline_rows("results/dryrun/*_multi.json")]
    text = replace_block(text, "ROOFLINE_TABLE", render_table(single))
    text = replace_block(text, "ROOFLINE_TABLE_MULTI", render_table(multi))
    text = replace_block(text, "ROOFLINE_ANALYSIS", render_analysis(single))
    open(MD, "w").write(text)
    print(f"filled EXPERIMENTS.md: {len(single)} single, {len(multi)} multi")


if __name__ == "__main__":
    main()
