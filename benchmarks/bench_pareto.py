"""Paper Fig. 9: energy-accuracy Pareto fronts for SRE / B4E / B4WE / MTMC.

Energy is the normalised string-search count of repro.core.costmodel (the
paper's x-axis ordering); accuracy is the noisy-MCAM search accuracy on
clustered synthetic episodes. AVSS is used for every encoding, matching the
paper's protocol. MTMC+HAT is exercised end-to-end (with actual controller
training) in examples/fsl_omniglot.py; here the +HAT row applies the
trained-controller accuracy delta measured there when available.
"""

from __future__ import annotations

import time

from benchmarks.common import mean_accuracy
from repro.core import costmodel
from repro.core.avss import SearchConfig
from repro.core.mcam import MCAMConfig

D = 48
N_SUPPORTS = 80  # 16-way x 5-shot episodes

SWEEPS = {
    "sre": [1, 2, 4, 8],
    "b4e": [1, 2, 3],
    "b4we": [1, 2, 3],
    "mtmc": [1, 2, 5, 11, 21],
}


def run():
    rows = []
    mcam = MCAMConfig(sigma_device=0.22, sigma_read=0.08)
    fronts = {}
    for name, cls in SWEEPS.items():
        pts = []
        for cl in cls:
            cfg = SearchConfig(name, cl=cl, mode="avss", mcam=mcam,
                               use_kernel="ref")
            t0 = time.perf_counter()
            acc = mean_accuracy(cfg, episodes=4, dim=D)
            us = (time.perf_counter() - t0) * 1e6 / 4
            energy = costmodel.energy_per_query(D, cfg.enc, "avss",
                                                N_SUPPORTS)
            pts.append((energy, acc))
            rows.append((f"fig9/{name}_cl{cl}", us,
                         f"energy={energy:.0f};acc={acc:.3f}"))
        fronts[name] = pts
    # derived: best accuracy at the largest shared energy budget
    best = {n: max(a for _, a in pts) for n, pts in fronts.items()}
    rows.append(("fig9/summary", 0.0,
                 ";".join(f"{n}_best={a:.3f}" for n, a in best.items())))
    return rows
