"""Run the full dry-run sweep: every (arch x shape) cell on the single-pod
(16x16) and multi-pod (2x16x16) production meshes, one subprocess per cell
(XLA_FLAGS + device-state isolation + memory hygiene on the 1-core runner).

    PYTHONPATH=src python -m benchmarks.dryrun_sweep [--mesh single|multi|both]
        [--only arch,arch] [--results DIR]

Resumable: cells with an existing result JSON are skipped.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    # cheap first: small decode/prefill cells compile in seconds
    "xlstm-350m", "hymba-1.5b", "musicgen-medium", "starcoder2-3b",
    "qwen2-vl-7b", "deepseek-moe-16b", "qwen1.5-110b",
    "command-r-plus-104b", "llama3-405b", "deepseek-v3-671b",
]
SHAPES = ["decode_32k", "long_500k", "prefill_32k", "train_4k"]

# the paper-representative extra cell: MCAM retrieval head attached
RETRIEVAL_CELLS = [("starcoder2-3b", "decode_32k")]


def run_one(arch, shape, mesh, results_dir, retrieval=False, timeout=3600):
    tag = f"{arch}_{shape}_{mesh}" + ("_mcam" if retrieval else "")
    out = os.path.join(results_dir, tag + ".json")
    if os.path.exists(out):
        print(f"[skip] {tag} (cached)")
        return json.load(open(out))
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", out]
    if retrieval:
        cmd.append("--retrieval")
    t0 = time.time()
    env = dict(os.environ)
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
    except subprocess.TimeoutExpired:
        rec = {"arch": arch, "shape": shape, "mesh": mesh,
               "status": "timeout"}
        json.dump(rec, open(out, "w"))
        print(f"[TIMEOUT] {tag}")
        return rec
    dt = time.time() - t0
    if proc.returncode != 0 or not os.path.exists(out):
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "status": "error",
               "stderr": proc.stderr[-4000:]}
        json.dump(rec, open(out, "w"), indent=1)
        print(f"[FAIL] {tag} ({dt:.0f}s)")
        print(proc.stderr[-1500:])
        return rec
    rec = json.load(open(out))
    r = rec.get("roofline", {})
    print(f"[ok] {tag} ({dt:.0f}s) status={rec['status']} "
          f"dominant={r.get('dominant', '-')} bound={r.get('bound_s', 0):.3g}s")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--only", default="")
    ap.add_argument("--shapes", default="")
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()
    os.makedirs(args.results, exist_ok=True)
    archs = args.only.split(",") if args.only else ARCHS
    shapes = args.shapes.split(",") if args.shapes else SHAPES
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    t0 = time.time()
    n = 0
    for mesh in meshes:
        for shape in shapes:
            for arch in archs:
                run_one(arch, shape, mesh, args.results,
                        timeout=args.timeout)
                n += 1
        if mesh == "single":
            for arch, shape in RETRIEVAL_CELLS:
                run_one(arch, shape, mesh, args.results, retrieval=True,
                        timeout=args.timeout)
    print(f"sweep done: {n} cells in {(time.time()-t0)/60:.1f} min")


if __name__ == "__main__":
    main()
