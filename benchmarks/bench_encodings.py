"""Paper Table 1: encoding rules -- correctness spot check + encode timing."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_us
from repro.core.encodings import make_encoding


def run():
    rows = []
    v = jnp.arange(16)
    mtmc = make_encoding("mtmc", 5)
    b4e = make_encoding("b4e", 2)
    got = "".join(str(int(c)) for c in np.asarray(mtmc.encode(v))[7])
    assert got == "11122", got          # Table 1, value 7
    got = "".join(str(int(c)) for c in np.asarray(b4e.encode(v))[7])
    assert got == "13", got
    big = jnp.arange(96 * 1024) % 97
    for name, cl in [("mtmc", 32), ("b4e", 3), ("sre", 5), ("b4we", 3)]:
        enc = make_encoding(name, cl)
        vv = big % enc.levels
        us, codes = time_us(lambda x: enc.encode(x), vv)
        rows.append((f"table1/encode_{name}_cl{cl}", us,
                     f"levels={enc.levels};words={enc.length}"))
    return rows
