"""Paper Fig. 3 / Fig. 5: mismatch-level distributions, B4E vs MTMC.

Reproduces the motivating analysis: as precision (code word length) grows,
B4E's share of mismatch-3 words grows and mismatch-3 appears even for CLOSE
value pairs, while MTMC keeps max-mismatch <= 1 for |a-b| < CL.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.encodings import make_encoding


def mismatch_histogram(enc):
    v = np.arange(enc.levels)
    import jax.numpy as jnp
    codes = np.asarray(enc.encode(jnp.asarray(v)))         # (levels, L)
    diffs = np.abs(codes[:, None] - codes[None])           # (lv, lv, L)
    hist = np.bincount(diffs.reshape(-1), minlength=4)[:4]
    return hist / hist.sum()


def p_mismatch3_close(enc, within):
    v = np.arange(enc.levels)
    import jax.numpy as jnp
    codes = np.asarray(enc.encode(jnp.asarray(v)))
    out = []
    for a in range(enc.levels):
        for b in range(enc.levels):
            if a != b and abs(a - b) <= within:
                out.append(np.abs(codes[a] - codes[b]).max() == 3)
    return float(np.mean(out)) if out else 0.0


def run():
    rows = []
    for cl_b4e, cl_mtmc in [(2, 5), (3, 21)]:
        # matched quantization levels: 4^cl_b4e == 3*cl_mtmc + 1
        b4e = make_encoding("b4e", cl_b4e)
        mtmc = make_encoding("mtmc", cl_mtmc)
        assert b4e.levels == mtmc.levels
        t0 = time.perf_counter()
        hb = mismatch_histogram(b4e)
        hm = mismatch_histogram(mtmc)
        p3b = p_mismatch3_close(b4e, within=cl_mtmc - 1)
        p3m = p_mismatch3_close(mtmc, within=cl_mtmc - 1)
        us = (time.perf_counter() - t0) * 1e6
        assert p3m == 0.0, "MTMC must never mismatch-3 for close pairs"
        rows.append((f"fig3_5/levels{b4e.levels}", us,
                     f"b4e_m3={hb[3]:.3f};mtmc_m3={hm[3]:.3f};"
                     f"b4e_m3_close={p3b:.3f};mtmc_m3_close={p3m:.3f}"))
    return rows
