"""Retrieval-engine throughput: full vs two-phase vs sharded two-phase.

Rows compare the three RetrievalEngine paths at a serving-shaped store
(N supports, B queries) plus backend variants of the shortlist. NOTE: on
this CPU container the Pallas rows measure the INTERPRETER; relative
ordering of ref-vs-two-phase and the sharded scaling shape are the signal,
not absolute wall-times (the TPU-side analysis lives in the roofline).

Run standalone for a multi-device sharded row:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.run --only engine
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks.common import time_percentiles
from repro.core.avss import SearchConfig
from repro.core.mcam import MCAMConfig
from repro.core.memory import MemoryConfig
from repro.engine import (MemoryStore, RetrievalEngine, SearchRequest)

# PINNED to the acceptance shape of the PR-5/6 shortlist baselines
# (BENCH_shortlist.json compares against rows at this exact N) -- do NOT
# follow IDEAL_FUSED_MIN_ROWS, which dropped to its measured crossover
# (1024) in PR 10 and is a dispatch knob, not a benchmark shape.
N_IDEAL = 4096
N, B, D, K = 2048, 16, 48, 64
W = 256                              # streaming-write batch rows


def run():
    rows = []
    cfg = SearchConfig("mtmc", cl=8, mode="avss", mcam=MCAMConfig(),
                       use_kernel="ref")
    enc = cfg.enc
    sv = jax.random.randint(jax.random.PRNGKey(0), (N, D), 0, enc.levels)
    qv = jax.random.randint(jax.random.PRNGKey(1), (B, D), 0, 4)

    def qps(us):
        return f"qps={B / us * 1e6:.0f}"

    # full exact search (reference backend)
    eng_ref = RetrievalEngine(cfg, backend="ref")
    f_full = jax.jit(lambda q, s: eng_ref.full(q, s)["votes"])
    st_full, votes_full = time_percentiles(f_full, qv, sv, iters=2)
    us_full = st_full["us"]
    rows.append((f"engine/full_N{N}", us_full,
                 qps(us_full) + ";backend=ref", st_full))

    # two-phase: MXU shortlist + exact rescore, per shortlist backend
    votes_tp = {}
    for backend in ("ref", "mxu", "fused"):
        eng = RetrievalEngine(cfg, backend=backend)
        f_tp = jax.jit(lambda q, s, e=eng: e.two_phase(q, s, k=K)["votes"])
        st_tp, votes_tp[backend] = time_percentiles(f_tp, qv, sv, iters=3)
        us_tp = st_tp["us"]
        rows.append((f"engine/two_phase_k{K}_{backend}", us_tp,
                     qps(us_tp) + f";speedup_vs_full={us_full / us_tp:.1f}x",
                     st_tp))
    for backend in ("mxu", "fused"):  # backends must agree bit-exactly
        np.testing.assert_array_equal(np.asarray(votes_tp["ref"]),
                                      np.asarray(votes_tp[backend]))

    # unified API: engine.search over a programmed MemoryStore (write-time
    # proj + s_grid layouts -- the serving path). Must be bit-identical to
    # the raw two-phase call AND at least as fast per query (no per-call
    # re-layout of the store).
    labels = jnp.arange(N, dtype=jnp.int32) % 128
    store = MemoryStore.from_quantized(sv, labels, cfg)
    req = SearchRequest(mode="two_phase", k=K)
    for backend in ("ref", "mxu", "fused"):
        eng = RetrievalEngine(cfg, backend=backend)
        f_st = jax.jit(lambda st, q, e=eng: e.search(st, q, req).votes)
        st_st, votes_st = time_percentiles(f_st, store, qv, iters=3)
        us_st = st_st["us"]
        rows.append((f"engine/search_store_k{K}_{backend}", us_st,
                     qps(us_st) + f";speedup_vs_full={us_full / us_st:.1f}x",
                     st_st))
        np.testing.assert_array_equal(np.asarray(votes_tp["ref"]),
                                      np.asarray(votes_st))

    # sharded two-phase over every local device (1 on a plain CPU run;
    # launch with XLA_FLAGS=--xla_force_host_platform_device_count=8 to see
    # the multi-shard shape)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    svs = jax.device_put(sv, NamedSharding(mesh, P("data")))
    eng = RetrievalEngine(cfg, backend="ref")
    with mesh:
        f_sh = jax.jit(lambda q, s: eng.sharded_two_phase(
            q, s, mesh, axes=("data",), k=K)["votes"])
        st_sh, votes_sh = time_percentiles(f_sh, qv, svs, iters=3)
    us_sh = st_sh["us"]
    rows.append((f"engine/sharded_two_phase_k{K}_dev{n_dev}", us_sh,
                 qps(us_sh) + f";shards={n_dev}", st_sh))
    np.testing.assert_array_equal(np.asarray(votes_tp["ref"]),
                                  np.asarray(votes_sh))

    # shard-aware store: the same search request against store.shard(...)
    # dispatches to the sharded path (labels folded into the merge)
    sstore = store.shard(mesh, ("data",))
    with mesh:
        f_ss = jax.jit(lambda st, q: eng.search(st, q, req).votes)
        st_ss, votes_ss = time_percentiles(f_ss, sstore, qv, iters=3)
    us_ss = st_ss["us"]
    rows.append((f"engine/search_sharded_k{K}_dev{n_dev}", us_ss,
                 qps(us_ss) + f";shards={n_dev}", st_ss))
    np.testing.assert_array_equal(np.asarray(votes_tp["ref"]),
                                  np.asarray(votes_ss))

    # streaming write (the paper's cheap operation): program a W-row batch
    # into a ring store, unsharded scatter vs the shard-local write-through
    # (1-device mesh here; the multi-shard shape lives in engine_sharded)
    mcfg = MemoryConfig(capacity=N, dim=D, search=cfg)
    wvecs = jax.random.normal(jax.random.PRNGKey(2), (W, D))
    wlabs = jnp.arange(W, dtype=jnp.int32)
    base = MemoryStore.create(mcfg).calibrate(wvecs)
    f_w = jax.jit(lambda st, v, l: st.write(v, l).values)
    st_w, _ = time_percentiles(f_w, base, wvecs, wlabs, iters=3)
    us_w = st_w["us"]
    rows.append((f"engine/write_scatter_b{W}", us_w,
                 f"rows_per_s={W / us_w * 1e6:.0f}", st_w))
    sbase = base.shard(mesh, ("data",))
    with mesh:
        f_ws = jax.jit(lambda st, v, l: st.write(v, l).values)
        st_ws, vals_ws = time_percentiles(f_ws, sbase, wvecs, wlabs,
                                          iters=3)
    us_ws = st_ws["us"]
    rows.append((f"engine/write_stream_b{W}_dev{n_dev}", us_ws,
                 f"rows_per_s={W / us_ws * 1e6:.0f};shards={n_dev}",
                 st_ws))
    np.testing.assert_array_equal(np.asarray(f_w(base, wvecs, wlabs)),
                                  np.asarray(vals_ws))

    # large-N ideal serving: dense (B, N) matmul vs the fused shortlist
    # kernel (HBM O(B*k + N*4d)); bit-parity asserted
    isv = jax.random.randint(jax.random.PRNGKey(3), (N_IDEAL, D), 0,
                             enc.levels)
    istore = MemoryStore.from_quantized(
        isv, jnp.arange(N_IDEAL, dtype=jnp.int32) % 128, cfg)
    ireq = SearchRequest(mode="ideal", k=K)
    f_id = {b: jax.jit(lambda st, q, e=RetrievalEngine(cfg, backend=b):
                       e.search(st, q, ireq)) for b in ("ref", "fused")}
    st_dense, res_dense = time_percentiles(f_id["ref"], istore, qv, iters=3)
    us_dense = st_dense["us"]
    rows.append((f"engine/ideal_dense_N{N_IDEAL}", us_dense, qps(us_dense),
                 st_dense))
    st_fused, res_fused = time_percentiles(f_id["fused"], istore, qv,
                                           iters=3)
    us_fused = st_fused["us"]
    rows.append((f"engine/ideal_fused_N{N_IDEAL}", us_fused,
                 qps(us_fused)
                 + f";speedup_vs_dense={us_dense / us_fused:.1f}x",
                 st_fused))
    for key in ("votes", "dist", "indices", "labels"):
        np.testing.assert_array_equal(np.asarray(getattr(res_dense, key)),
                                      np.asarray(getattr(res_fused, key)))

    # multi-tenant serving (PR 9): B queries spread over T stacked stores,
    # ONE coalesced vmapped search_tenants call vs T sequential solo
    # engine.search calls over the same queries -- the coalesced path must
    # be bit-identical per query and is the one the TenantServer shell
    # batches into. Stores are small (serving-shaped: many tenants, few
    # rows each); the signal is the per-T scaling of coalesced dispatch
    # overhead vs the sequential python loop, not absolute wall-time.
    from repro.engine import TenantStore
    t_cap, t_dim = 64, 16
    tcfg = SearchConfig("mtmc", cl=8, mode="avss", use_kernel="ref")
    treq = SearchRequest(mode="two_phase", k=8)
    teng = RetrievalEngine(tcfg)
    f_co = jax.jit(lambda ts, q, i: teng.search_tenants(ts, q, i, treq))
    for T in (1, 8, 64):
        tstores = [MemoryStore.from_quantized(
            jax.random.randint(jax.random.PRNGKey(10 + t), (t_cap, t_dim),
                               0, tcfg.enc.levels),
            jax.random.randint(jax.random.PRNGKey(200 + t), (t_cap,),
                               0, 16), tcfg) for t in range(T)]
        tts = TenantStore.stack(tstores)
        tq = jax.random.randint(jax.random.PRNGKey(300 + T), (B, t_dim),
                                0, 4)
        tids = jax.random.randint(jax.random.PRNGKey(400 + T), (B,), 0, T)
        st_co, res_co = time_percentiles(f_co, tts, tq, tids, iters=3)
        us_co = st_co["us"]
        rows.append((f"engine/tenants_coalesced_T{T}", us_co,
                     qps(us_co) + f";tenants={T}", st_co))

        # sequential: one solo search per tenant group (what serving
        # without the stack would do) -- parity-asserted against the
        # coalesced rows, timing includes the per-tenant dispatch loop
        tid_np = np.asarray(tids)
        groups = [(t, np.where(tid_np == t)[0]) for t in range(T)
                  if (tid_np == t).any()]
        f_solo = jax.jit(lambda st, q, e=teng: e.search(st, q, treq))

        def seq(ts_q=tq, gs=groups, sts=tstores):
            out = [f_solo(sts[t], ts_q[jnp.asarray(sel)]) for t, sel in gs]
            jax.block_until_ready(out)
            return out

        st_seq, res_seq = time_percentiles(seq, iters=3)
        us_seq = st_seq["us"]
        rows.append((f"engine/tenants_sequential_T{T}", us_seq,
                     qps(us_seq)
                     + f";coalesced_speedup={us_seq / us_co:.1f}x",
                     st_seq))
        for (t, sel), solo in zip(groups, res_seq):
            np.testing.assert_array_equal(
                np.asarray(res_co.labels[jnp.asarray(sel)]),
                np.asarray(solo.labels))

    # two-phase recall@k of the 1-NN decision vs the full search
    from repro.core import avss as avss_lib
    full = eng_ref.full(qv, sv)
    full_best = np.asarray(avss_lib.best_support(full))
    tp = eng_ref.two_phase(qv, sv, k=K)
    best = np.asarray(avss_lib.best_support(tp))
    tp_best = np.asarray(tp["indices"])[np.arange(B), best]
    rows.append((f"engine/two_phase_recall_k{K}", 0.0,
                 f"recall={float((full_best == tp_best).mean()):.2f}"))
    return rows
