"""End-to-end LM training driver on synthetic data (reduced config on CPU;
the identical code path the dry-run proves out at 405B/671B scale).

    PYTHONPATH=src python examples/train_llm.py --arch starcoder2-3b \
        --steps 100 --batch 8 --seq 128

Uses the full production substrate: sharded train step (grad accumulation,
clipping, in-step anomaly skip), AdamW, async atomic checkpoints, preemption
handler, resumable deterministic data. Try Ctrl-C mid-run then re-run with
--resume: training continues from the checkpoint, replaying no data.
"""

from repro.launch.train import main

if __name__ == "__main__":
    main()
