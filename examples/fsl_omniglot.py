"""Paper-faithful end-to-end driver: Conv4 controller + HAT on procedural
Omniglot-like data, then the paper's evaluation matrix.

    PYTHONPATH=src python examples/fsl_omniglot.py \
        [--pretrain-steps 150] [--meta-steps 120] [--n-way 8] [--full]

Two-stage HAT training (paper Sec. 3.3):
  stage 1: controller + linear classifier, plain CE on all training classes;
  stage 2: episodic meta-training THROUGH the simulated MCAM (asymmetric
           fake-quant, MTMC STE, string currents + noise, sigmoid-STE SA,
           vote-based CE).
Evaluation: accuracy of {MTMC, B4E, SRE} x {standard, HAT} controllers and
SVSS vs AVSS, on held-out classes -- the deltas mirror paper Fig. 9/Table 2.
`--full` uses the paper's 200-way 10-shot geometry (slow on CPU).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.omniglot_conv4 import get_config, get_smoke_config
from repro.core import hat
from repro.core.avss import SearchConfig
from repro.core.hat import HATConfig, meta_loss, pretrain_loss
from repro.core.mcam import MCAMConfig
from repro.core.quantization import quantize_asymmetric, fake_quant, QuantSpec
from repro.data.fsl import EpisodeSampler, OmniglotLike, pretrain_batch
from repro.models.controller import apply_conv4, init_conv4
from repro.optim import adamw


def embed_apply(params, images):
    return apply_conv4(params, images)


def evaluate(params, sampler, search_cfg, episodes=6, backend="auto",
             two_phase=False, k=64):
    """Episode accuracy through the unified retrieval API: each episode's
    quantized supports are programmed into a MemoryStore (write-time MCAM
    layouts) and searched via engine.search with one typed request.

    two_phase=True evaluates the production serving path (MXU shortlist +
    exact noisy rescore) instead of the full search -- accuracies match
    whenever the 1-NN makes the shortlist (recall@k, see bench_engine)."""
    from repro.engine import MemoryStore, RetrievalEngine, SearchRequest
    engine = RetrievalEngine(search_cfg, backend=backend)
    request = SearchRequest(mode="two_phase" if two_phase else "full", k=k)
    accs = []
    for e in range(episodes):
        ep = sampler.episode(1000 + e)
        s_emb = embed_apply(params["backbone"], jnp.asarray(ep.support_images))
        q_emb = embed_apply(params["backbone"], jnp.asarray(ep.query_images))
        if search_cfg.mode == "avss":
            qv, sv = quantize_asymmetric(q_emb, s_emb, search_cfg.enc.levels)
        else:
            sv, _, rng = fake_quant(s_emb, QuantSpec(search_cfg.enc.levels))
            qv, _, _ = fake_quant(q_emb, QuantSpec(search_cfg.enc.levels), rng)
        qv, sv = qv.astype(jnp.int32), sv.astype(jnp.int32)
        s_lab = jnp.asarray(ep.support_labels)
        store = MemoryStore.from_quantized(sv, s_lab, search_cfg)
        pred = engine.search(store, qv, request).predict()
        accs.append(float((pred == jnp.asarray(ep.query_labels)).mean()))
    return float(np.mean(accs)), float(np.std(accs))


def train_controller(fsl, ds, train_ids, hat_cfg, args, use_hat=True,
                     seed=0):
    key = jax.random.PRNGKey(seed)
    backbone = init_conv4(key, in_ch=1, width=32, embed_dim=fsl.embed_dim)
    head = {"w": jax.random.normal(jax.random.PRNGKey(1),
                                   (fsl.embed_dim, len(train_ids))) * 0.05,
            "b": jnp.zeros((len(train_ids),))}
    params = {"backbone": backbone, "head": head}
    opt = adamw(1e-3, weight_decay=1e-4)
    opt_state = opt.init(params)

    @jax.jit
    def pre_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(pretrain_loss)(
            params, batch, embed_apply)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
        return params, opt_state, loss

    t0 = time.time()
    for step in range(args.pretrain_steps):
        batch = pretrain_batch(ds, train_ids, batch=32, step=step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, loss = pre_step(params, opt_state, batch)
        if step % 50 == 0:
            print(f"  [pretrain] step {step} loss {float(loss):.3f} "
                  f"({time.time()-t0:.0f}s)")

    if not use_hat:
        return params

    # stage 2: episodic meta-training through the simulated MCAM
    sampler = EpisodeSampler(ds, train_ids, n_way=args.n_way,
                             k_shot=fsl.k_shot, n_query=4, seed=11)
    opt2 = adamw(1e-4, weight_decay=1e-4)  # gentle: adapt, don't destroy
    meta_params = {"backbone": params["backbone"]}
    opt_state2 = opt2.init(meta_params)

    n_way_static = args.n_way  # keep out of the traced pytree

    @jax.jit
    def meta_step(params, opt_state, ep_arrays, key):
        episode = {**ep_arrays, "n_way": n_way_static}
        loss, grads = jax.value_and_grad(meta_loss)(
            params, episode, lambda p, x: embed_apply(p, x), hat_cfg, key)
        updates, opt_state = opt2.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
        return params, opt_state, loss

    for step in range(args.meta_steps):
        ep = sampler.episode(step)
        episode = {"support_images": jnp.asarray(ep.support_images),
                   "support_labels": jnp.asarray(ep.support_labels),
                   "query_images": jnp.asarray(ep.query_images),
                   "query_labels": jnp.asarray(ep.query_labels)}
        meta_params, opt_state2, loss = meta_step(
            meta_params, opt_state2, episode, jax.random.PRNGKey(step))
        if step % 40 == 0:
            print(f"  [meta/HAT] step {step} loss {float(loss):.3f} "
                  f"({time.time()-t0:.0f}s)")
    return {"backbone": meta_params["backbone"], "head": params["head"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pretrain-steps", type=int, default=150)
    ap.add_argument("--meta-steps", type=int, default=120)
    ap.add_argument("--n-way", type=int, default=8)
    ap.add_argument("--full", action="store_true",
                    help="paper geometry (200-way 10-shot, CL=32); slow")
    ap.add_argument("--engine-backend", default="auto",
                    choices=["auto", "ref", "pallas", "mxu", "fused"])
    ap.add_argument("--two-phase-eval", action="store_true",
                    help="evaluate via the two-phase engine path "
                         "(shortlist + exact rescore) instead of full search")
    ap.add_argument("--shortlist-k", type=int, default=64)
    args = ap.parse_args()

    fsl = get_config() if args.full else get_smoke_config()
    if not args.full:
        fsl = type(fsl)(**{**fsl.__dict__, "k_shot": 5})
    ds = OmniglotLike(n_classes=fsl.n_train_classes + fsl.n_test_classes,
                      image_size=fsl.image_size, seed=0)
    train_ids = np.arange(fsl.n_train_classes)
    test_ids = np.arange(fsl.n_train_classes,
                         fsl.n_train_classes + fsl.n_test_classes)

    mcam = MCAMConfig(sigma_device=0.15, sigma_read=0.05)
    cl = fsl.cl
    hat_cfg = HATConfig(search=SearchConfig("mtmc", cl=cl, mode="avss",
                                            mcam=mcam, use_kernel="ref"))

    print("== training controller WITHOUT HAT (standard 2-stage of [24]) ==")
    params_std = train_controller(fsl, ds, train_ids, hat_cfg, args,
                                  use_hat=False)
    print("== training controller WITH HAT (paper Sec. 3.3) ==")
    params_hat = train_controller(fsl, ds, train_ids, hat_cfg, args,
                                  use_hat=True)

    n_way = min(args.n_way, len(test_ids))
    sampler = EpisodeSampler(ds, test_ids, n_way=n_way, k_shot=fsl.k_shot,
                             n_query=4, seed=77)

    print(f"\n== evaluation on {len(test_ids)} held-out classes "
          f"({n_way}-way {fsl.k_shot}-shot, noisy MCAM) ==")
    results = {}
    for label, params in [("std", params_std), ("HAT", params_hat)]:
        for enc_name, ecl in [("mtmc", cl), ("b4e", 3), ("sre", 4)]:
            cfg = SearchConfig(enc_name, cl=ecl, mode="avss", mcam=mcam,
                               use_kernel="ref")
            acc, sd = evaluate(params, sampler, cfg,
                               backend=args.engine_backend,
                               two_phase=args.two_phase_eval,
                               k=args.shortlist_k)
            results[(label, enc_name)] = acc
            print(f"  {label:4s} {enc_name:5s} AVSS: {acc:.3f} +- {sd:.3f}")
    for mode in ("svss", "avss"):
        cfg = SearchConfig("mtmc", cl=cl, mode=mode, mcam=mcam,
                           use_kernel="ref")
        acc, sd = evaluate(params_hat, sampler, cfg,
                           backend=args.engine_backend)
        print(f"  HAT  mtmc {mode.upper()}: {acc:.3f} +- {sd:.3f}")

    d_hat = results[("HAT", "mtmc")] - results[("std", "mtmc")]
    d_enc = results[("HAT", "mtmc")] - results[("HAT", "b4e")]
    print(f"\n  HAT gain (mtmc):          {d_hat:+.3f}   (paper: +1.25%..1.8%)")
    print(f"  MTMC vs B4E (HAT ctrl):   {d_enc:+.3f}   (paper: +0.34%..4.91%)")

    serve_loop_check(params_hat, sampler, hat_cfg)


def serve_loop_check(params, sampler, hat_cfg):
    """Close the train->write->serve loop: the HAT controller's noiseless
    in-training scores (engine.episode_scores -- the exact forward stage 2
    trained through) must be BIT-IDENTICAL to serving the same supports
    through MemoryStore.calibrate/write + engine.search. This is the
    train/serve parity contract (tests/test_train_serve_parity.py)."""
    from repro.core.avss import class_mean_votes
    from repro.engine import MemoryStore, RetrievalEngine, SearchRequest
    eng = RetrievalEngine(hat_cfg.search)
    ep = sampler.episode(4242)
    s_emb = embed_apply(params["backbone"], jnp.asarray(ep.support_images))
    q_emb = embed_apply(params["backbone"], jnp.asarray(ep.query_images))
    s_lab = jnp.asarray(ep.support_labels)
    scores = eng.episode_scores(q_emb, s_emb, s_lab, ep.n_way,
                                clip_std=hat_cfg.clip_std,
                                sa_tau=hat_cfg.sa_tau, noisy=False)
    store = MemoryStore.from_episode(s_emb, q_emb, s_lab, hat_cfg.search,
                                     clip_std=hat_cfg.clip_std)
    res = eng.search(store, q_emb, SearchRequest(mode="full", noisy=False))
    served = class_mean_votes(res.votes, store.labels, ep.n_way)
    print(f"\n== train->write->serve loop ==\n"
          f"  in-training scores == served scores (bitwise): "
          f"{bool(jnp.array_equal(scores, served))}")


if __name__ == "__main__":
    main()
