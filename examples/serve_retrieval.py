"""End-to-end SERVING driver (the paper's kind: efficient retrieval):
a small LM decodes batched requests with an MCAM-backed kNN memory fused
into the logits -- the production `serve_step` the 40-cell dry-run lowers,
executed for real at reduced scale.

    PYTHONPATH=src python examples/serve_retrieval.py \
        [--arch starcoder2-3b] [--batch 4] [--steps 12] [--lam 0.3]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import load_config
from repro.core.avss import SearchConfig
from repro.core.memory import MemoryConfig
from repro.engine import MemoryStore
from repro.launch import steps as steps_lib
from repro.models import transformer as tfm
from repro.models.sharding import Rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--lam", type=float, default=0.3)
    args = ap.parse_args()

    cfg = load_config(args.arch, smoke=True)
    rules = Rules(batch=(), fsdp=(), tensor=(), expert=())
    key = jax.random.PRNGKey(0)
    params = tfm.init(key, cfg)
    B, P = args.batch, args.prompt_len
    max_seq = P + args.steps

    # --- the MCAM memory: token-labelled embedding store (kNN-LM head) ---
    # programmed ONCE at write time (quantized values + LUT projection +
    # string-grid layout); the decode loop jits against the constants
    mem_cfg = MemoryConfig(
        capacity=1024, dim=min(48, cfg.d_model),
        search=SearchConfig("mtmc", cl=8, mode="avss", use_kernel="ref"))
    demo_vecs = jax.random.normal(jax.random.PRNGKey(7), (256, mem_cfg.dim))
    demo_tok = jax.random.randint(jax.random.PRNGKey(8), (256,), 0,
                                  cfg.vocab_size)
    mstate = (MemoryStore.create(mem_cfg).calibrate(demo_vecs)
              .write(demo_vecs, demo_tok))

    serve_step = steps_lib.make_serve_step_with_mcam(cfg, rules, mem_cfg,
                                                     lam=args.lam)
    jstep = jax.jit(serve_step)
    plain_step = jax.jit(steps_lib.make_serve_step(cfg, rules))

    # --- batched requests: prefill then decode ---
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    caches = tfm.init_cache(cfg, B, max_seq)
    print(f"prefilling {B} requests of {P} tokens ...")
    t0 = time.time()
    for t in range(P):  # teacher-forced prefill through the decode path
        logits, caches = plain_step(params, caches,
                                    {"tokens": prompts[:, t:t + 1]},
                                    jnp.int32(t))
    print(f"  prefill {time.time()-t0:.1f}s")

    tok = jnp.argmax(logits[:, 0], -1)[:, None]
    outs = [tok]
    t0 = time.time()
    for i in range(args.steps):
        logits, caches = jstep(params, caches, {"tokens": tok},
                               jnp.int32(P + i), mstate)
        tok = jnp.argmax(logits[:, 0], -1)[:, None]
        outs.append(tok)
    dt = time.time() - t0
    gen = np.asarray(jnp.concatenate(outs, 1))
    print(f"decoded {args.steps} steps x {B} requests in {dt:.1f}s "
          f"({args.steps * B / dt:.1f} tok/s on CPU, MCAM-fused logits)")
    for b in range(B):
        print(f"  req{b}: {gen[b].tolist()}")
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print("OK: serve_step_with_mcam end-to-end")


if __name__ == "__main__":
    main()
