"""Quickstart: the paper's MCAM vector-similarity search in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Build an MCAM-backed external memory (MTMC-encoded, AVSS search mode).
2. Write clustered support embeddings; search noisy queries.
3. Compare iteration counts / throughput of AVSS vs SVSS (paper Table 2).
4. Two-phase TPU pipeline: MXU LUT shortlist + exact noisy rescore.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel
from repro.core.avss import SearchConfig, search_iterations
from repro.core.memory import MemoryConfig
from repro.engine import MemoryStore, RetrievalEngine, SearchRequest


def main():
    key = jax.random.PRNGKey(0)
    n_way, k_shot, dim, cl = 20, 10, 48, 32

    centers = jax.random.normal(key, (n_way, dim)) * 2.0
    s_lab = jnp.repeat(jnp.arange(n_way), k_shot)
    support = centers[s_lab] + 0.3 * jax.random.normal(
        jax.random.PRNGKey(1), (n_way * k_shot, dim))
    queries = centers + 0.3 * jax.random.normal(jax.random.PRNGKey(2),
                                                centers.shape)

    cfg = MemoryConfig(capacity=512, dim=dim,
                       search=SearchConfig("mtmc", cl=cl, mode="avss"))
    # program once: quantized values, MTMC LUT projection AND string-grid
    # layout are all materialised at write time (real MCAM programming)
    store = MemoryStore.create(cfg).calibrate(support).write(support, s_lab)
    engine = RetrievalEngine(cfg.search)

    res = engine.search(store, queries, SearchRequest(mode="full"))
    acc = float((res.predict() == jnp.arange(n_way)).mean())
    print(f"[full search]      accuracy {acc:.2%} "
          f"({n_way}-way {k_shot}-shot, MTMC CL={cl}, noisy MCAM)")

    res2 = engine.search(store, queries, SearchRequest(mode="two_phase",
                                                       k=32))
    acc2 = float((res2.predict() == jnp.arange(n_way)).mean())
    print(f"[two-phase search] accuracy {acc2:.2%} "
          f"(MXU LUT shortlist k=32 + exact rescore)")

    enc = cfg.search.enc
    it_avss = search_iterations(dim, enc, "avss")
    it_svss = search_iterations(dim, enc, "svss")
    print(f"[iterations]       SVSS {it_svss}  vs  AVSS {it_avss}  "
          f"({it_svss // it_avss}x fewer word-line cycles)")
    print(f"[throughput]       SVSS "
          f"{costmodel.throughput_searches_per_s(dim, enc, 'svss'):.1f}/s vs "
          f"AVSS {costmodel.throughput_searches_per_s(dim, enc, 'avss'):.0f}/s")
    print(f"[capacity]         {costmodel.strings_used(dim, enc, len(s_lab))}"
          f" NAND strings used of 131072 per block")


if __name__ == "__main__":
    main()
