from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adafactor, adamw, adamw8bit, make_optimizer, sgd,
    clip_by_global_norm, warmup_cosine, global_norm)
