"""Optimizers (optax-like protocol, self-contained).

* adamw      -- configurable moment dtype (fp32 / bf16): at 100B+ scale the
               moment dtype dominates HBM; bf16 moments halve optimizer state.
* adamw8bit  -- int8-quantized moments with per-block absmax scales
               (block = trailing 256 elems), the 8-bit-Adam trick: 4x less
               optimizer HBM than fp32, enabling 671B training on one pod.
* adafactor  -- factored second moment for >=2D params (row/col statistics).
* sgd        -- momentum SGD (baseline).

All states inherit the PARAM sharding (FSDP rows), i.e. ZeRO: the partitioner
shards moments exactly like the weights they track.

Schedules: warmup + cosine. Gradient utilities: global-norm clipping and the
int8 gradient-compression codec used by the distributed train step.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return sched


def _as_schedule(lr) -> Schedule:
    return lr if callable(lr) else (lambda step: jnp.float32(lr))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree_util.tree_map(lambda x: x * scale.astype(x.dtype), grads), g


# ---------------------------------------------------------------------------
# AdamW (configurable moment dtype).
# ---------------------------------------------------------------------------


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          state_dtype=jnp.float32) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mh = m32 / bc1
            vh = v32 / bc2
            u = -lr_t * (mh / (jnp.sqrt(vh) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype), m32.astype(state_dtype), \
                v32.astype(state_dtype)

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree_util.tree_map(lambda o: o[0], out,
                                         is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree_util.tree_map(lambda o: o[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# 8-bit AdamW: int8 moments + per-block absmax scales.
# ---------------------------------------------------------------------------

_BLOCK = 256


def _q8(x32: jax.Array):
    flat = x32.reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def adamw8bit(lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        def z(p):
            q, s = _q8(jnp.zeros(p.shape, jnp.float32))
            return {"q": q, "s": s}
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m32 = b1 * _dq8(m["q"], m["s"], p.shape) + (1 - b1) * gf
            v32 = b2 * _dq8(v["q"], v["s"], p.shape) + (1 - b2) * gf * gf
            v32 = jnp.maximum(v32, 0.0)
            u = -lr_t * ((m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
                         + weight_decay * p.astype(jnp.float32))
            mq, ms = _q8(m32)
            vq, vs = _q8(v32)
            return u.astype(p.dtype), {"q": mq, "s": ms}, {"q": vq, "s": vs}

        leaf = lambda x: isinstance(x, dict) and set(x) == {"q", "s"}
        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                     params, is_leaf=leaf)
        istup = lambda x: isinstance(x, tuple)
        updates = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=istup)
        m = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=istup)
        v = jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=istup)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments).
# ---------------------------------------------------------------------------


def adafactor(lr, decay=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        def z(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree_util.tree_map(z, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(g, f, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if p.ndim >= 2:
                vr = beta * f["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * f["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1)[..., None, None], eps))
                u = gf * jax.lax.rsqrt(denom + eps)
                nf = {"vr": vr, "vc": vc}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(v + eps)
                nf = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = -lr_t * (u + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype), nf

        leaf = lambda x: isinstance(x, dict) and (
            set(x) == {"vr", "vc"} or set(x) == {"v"})
        out = jax.tree_util.tree_map(upd, grads, state["f"], params,
                                     is_leaf=leaf)
        istup = lambda x: isinstance(x, tuple)
        updates = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=istup)
        f = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=istup)
        return updates, {"f": f, "step": step}

    return Optimizer(init, update)


def sgd(lr, momentum=0.9) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {"mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        mu = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state["mu"], grads)
        updates = jax.tree_util.tree_map(
            lambda m, p: (-lr_t * m).astype(p.dtype), mu, params)
        return updates, {"mu": mu, "step": step}

    return Optimizer(init, update)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    return {"adamw": adamw, "adamw8bit": adamw8bit,
            "adafactor": adafactor, "sgd": sgd}[name](lr, **kw)
