"""Serving launcher: batched-request decode loop with optional MCAM
retrieval fusion (reduced configs run on CPU; the dry-run lowers the same
serve_step for the production meshes).

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b --smoke \
        --batch 4 --steps 16 [--retrieval]

Multi-tenant retrieval serving (PR 9): `TenantServer` below is the minimal
coalescing shell over `RetrievalEngine.search_tenants` -- concurrent
per-tenant queries accumulate into one device batch, run as ONE compiled
search over the stacked `TenantStore`, and scatter back per ticket. The
standalone demo:

    PYTHONPATH=src python -m repro.launch.serve --tenants 8 --steps 16
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import load_config
from repro.launch import steps as steps_lib
from repro.models import transformer as tfm
from repro.models.sharding import Rules


class TenantServer:
    """Coalesce concurrent per-tenant queries into one compiled search.

    `submit(tenant_id, query)` enqueues and returns a ticket; `flush()`
    gathers the queue into one `(B, d)` batch + `(B,)` tenant_ids, runs
    a SINGLE jitted `search_tenants` call, and scatters each result row
    back to its ticket. Per-tenant ring writes go through
    `TenantStore.write_at`, which keeps every leaf shape -- so writes
    NEVER retrace the search (`cache_entries` stays flat; asserted in
    tests/test_tenant.py). The search program is shape-polymorphic only
    in the usual jit sense: one cache entry per distinct (B, T) shape.
    """

    def __init__(self, engine, tstore, request):
        self.engine = engine
        self.tstore = tstore
        self.request = request
        self._queue: list[tuple[jax.Array, int]] = []  # (query, tenant_id)
        self._search = jax.jit(
            partial(self._search_impl, engine), static_argnames=("req",))

    @staticmethod
    def _search_impl(engine, tstore, q, tids, req):
        return engine.search_tenants(tstore, q, tids, req)

    def submit(self, tenant_id: int, query: jax.Array) -> int:
        """Enqueue one query for one tenant; returns its ticket (the
        row the next `flush()` will hand back for it)."""
        self._queue.append((query, int(tenant_id)))
        return len(self._queue) - 1

    def flush(self):
        """Run the queued queries as ONE coalesced device batch and
        return {ticket: 1-query SearchResult} (batch axis kept, so
        `.predict()` / `.best()` work per ticket)."""
        if not self._queue:
            return {}
        q = jnp.stack([query for query, _ in self._queue])
        tids = jnp.asarray([t for _, t in self._queue], jnp.int32)
        self._queue = []
        res = self._search(self.tstore, q, tids, self.request)
        return {i: jax.tree_util.tree_map(lambda a: a[i:i + 1], res)
                for i in range(q.shape[0])}

    def write(self, tenant_id: int, vectors: jax.Array,
              labels: jax.Array) -> None:
        """Per-tenant ring write-through; leaf shapes are preserved so
        the compiled search is not retraced."""
        self.tstore = self.tstore.write_at(tenant_id, vectors, labels)

    def cache_entries(self) -> int:
        return self._search._cache_size()


def serve_tenants(n_tenants: int, steps: int, batch: int, dim: int = 16,
                  capacity: int = 32, mode: str = "two_phase",
                  backend: str = "auto", k: int = 8, seed: int = 0):
    """Standalone multi-tenant retrieval demo: T calibrated stores, a
    decode-loop of coalesced search batches interleaved with per-tenant
    ring writes -- prints throughput and the jit cache entry count
    (which must stay at 1 regardless of T or the write traffic)."""
    from repro.core.avss import SearchConfig
    from repro.core.memory import MemoryConfig
    from repro.engine import (MemoryStore, RetrievalEngine, SearchRequest,
                              TenantStore)
    scfg = SearchConfig("mtmc", cl=8, mode="avss", use_kernel="ref")
    mem_cfg = MemoryConfig(capacity=capacity, dim=dim, search=scfg)
    key = jax.random.PRNGKey(seed)
    stores = []
    for t in range(n_tenants):
        kt = jax.random.fold_in(key, t)
        vecs = jax.random.normal(kt, (capacity, dim))
        labs = jax.random.randint(jax.random.fold_in(kt, 1), (capacity,),
                                  0, 16)
        stores.append(MemoryStore.create(mem_cfg).calibrate(vecs)
                      .write(vecs, labs))
    server = TenantServer(RetrievalEngine(scfg, backend=backend),
                          TenantStore.stack(stores),
                          SearchRequest(mode=mode, k=k))
    t0 = time.time()
    for step in range(steps):
        ks = jax.random.fold_in(key, 10_000 + step)
        tids = np.asarray(jax.random.randint(ks, (batch,), 0, n_tenants))
        q = jax.random.normal(jax.random.fold_in(ks, 1), (batch, dim))
        tickets = [server.submit(int(tids[i]), q[i]) for i in range(batch)]
        out = server.flush()
        assert sorted(out) == tickets
        if step % 4 == 3:  # interleaved ring writes must not retrace
            server.write(int(tids[0]),
                         jax.random.normal(jax.random.fold_in(ks, 2),
                                           (2, dim)),
                         jnp.array([3, 5]))
    preds = jnp.concatenate([out[i].predict() for i in sorted(out)])
    preds.block_until_ready()
    dt = time.time() - t0
    entries = server.cache_entries()
    print(f"tenants={n_tenants}: {steps} flushes x {batch} queries in "
          f"{dt:.2f}s ({steps * batch / dt:.1f} q/s), "
          f"jit cache entries={entries}")
    assert entries == 1, f"per-tenant retrace detected: {entries} entries"
    return preds


def serve(arch: str, smoke: bool, batch: int, steps: int, prompt_len: int,
          retrieval: bool = False, retrieval_mode: str = "two-phase",
          retrieval_backend: str = "auto", retrieval_k: int = 32,
          retrieval_fused_min_rows: int | None = None,
          retrieval_shards: int | None = None,
          retrieval_nprobe: int | None = None):
    cfg = load_config(arch, smoke=smoke)
    rules = Rules(batch=(), fsdp=(), tensor=(), expert=())
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    max_seq = prompt_len + steps
    caches = tfm.init_cache(cfg, batch, max_seq)
    step_fn = jax.jit(steps_lib.make_serve_step(cfg, rules))

    mstate = mem_cfg = None
    if retrieval:
        from repro.core.avss import SearchConfig
        from repro.core.memory import MemoryConfig
        from repro.engine import MemoryStore, RetrievalEngine
        mem_cfg = MemoryConfig(capacity=1024, dim=min(48, cfg.d_model),
                               search=SearchConfig("mtmc", cl=8, mode="avss",
                                                   use_kernel="ref"))
        vecs = jax.random.normal(jax.random.PRNGKey(7), (256, mem_cfg.dim))
        toks = jax.random.randint(jax.random.PRNGKey(8), (256,), 0,
                                  cfg.vocab_size)
        # program once at write time (values + proj + s_grid); the decode
        # loop below jits against the store's constant layouts
        mstate = MemoryStore.create(mem_cfg).calibrate(vecs).write(vecs, toks)
        if retrieval_shards:
            # logical row partition; with --retrieval-nprobe < shards the
            # decode loop routes through the per-shard sketch
            # (repro/engine/router.py) instead of searching every shard
            mstate = mstate.shard(n_shards=retrieval_shards)
        # fused-threshold override (e.g. a TPU-measured dense-vs-fused
        # crossover) applies engine-wide without a code change
        eng_kw = {} if retrieval_fused_min_rows is None else \
            {"fused_min_rows": retrieval_fused_min_rows}
        engine = (RetrievalEngine(mem_cfg.search, backend=retrieval_backend,
                                  **eng_kw)
                  if retrieval_mode in ("two-phase", "ideal") else None)
        mode = "ideal" if retrieval_mode == "ideal" else "two_phase"
        step_fn = jax.jit(steps_lib.make_serve_step_with_mcam(
            cfg, rules, mem_cfg, engine=engine, k=retrieval_k, mode=mode,
            nprobe=retrieval_nprobe))

    key = jax.random.PRNGKey(1)
    tok = jax.random.randint(key, (batch, 1), 0, cfg.vocab_size)
    for t in range(prompt_len):  # warm the cache with a random prompt
        args = (params, caches, {"tokens": tok}, jnp.int32(t))
        out = step_fn(*args, mstate) if retrieval else step_fn(*args)
        logits, caches = out
        tok = jax.random.randint(jax.random.fold_in(key, t), (batch, 1), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    toks = []
    for i in range(steps):
        args = (params, caches, {"tokens": tok}, jnp.int32(prompt_len + i))
        out = step_fn(*args, mstate) if retrieval else step_fn(*args)
        logits, caches = out
        tok = jnp.argmax(logits[:, 0], -1)[:, None]
        toks.append(np.asarray(tok))
    dt = time.time() - t0
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(f"{arch}: {steps} steps x {batch} reqs in {dt:.2f}s "
          f"({steps * batch / dt:.1f} tok/s)")
    return np.concatenate(toks, 1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--retrieval", action="store_true")
    ap.add_argument("--retrieval-mode", default="two-phase",
                    choices=["dense", "two-phase", "ideal"],
                    help="dense: softmax over the whole store (legacy "
                         "comparison path); two-phase: engine shortlist + "
                         "exact noisy rescore; ideal: engine top-k by exact "
                         "digital distance only (cheapest; streams through "
                         "the fused shortlist kernel at large N)")
    ap.add_argument("--retrieval-backend", default="auto",
                    choices=["auto", "ref", "pallas", "mxu", "fused"])
    ap.add_argument("--retrieval-k", type=int, default=32)
    ap.add_argument("--retrieval-fused-min-rows", type=int, default=None,
                    help="override the fused-shortlist row threshold "
                         "(engine.IDEAL_FUSED_MIN_ROWS default; applies "
                         "per shard-local block on sharded stores) -- a "
                         "perf knob, results are bit-identical either way")
    ap.add_argument("--retrieval-shards", type=int, default=None,
                    help="partition the serve store into this many logical "
                         "row shards (MemoryStore.shard(n_shards=...)); "
                         "prerequisite for --retrieval-nprobe routing")
    ap.add_argument("--retrieval-nprobe", type=int, default=None,
                    help="shards visited per query ('two-phase'/'ideal' on "
                         "a partitioned store): < shards engages the "
                         "phase-0 router sketch, bit-identical to brute "
                         "force restricted to the visited shards; default "
                         "searches every shard")
    ap.add_argument("--tenants", type=int, default=None,
                    help="run the standalone multi-tenant retrieval demo "
                         "with this many tenant stores instead of the "
                         "transformer decode loop (TenantServer coalescing "
                         "shell over RetrievalEngine.search_tenants)")
    args = ap.parse_args(argv)
    if args.tenants is not None:
        serve_tenants(args.tenants, args.steps, args.batch,
                      backend=args.retrieval_backend, k=args.retrieval_k)
        return
    serve(args.arch, args.smoke, args.batch, args.steps, args.prompt_len,
          args.retrieval, args.retrieval_mode, args.retrieval_backend,
          args.retrieval_k, args.retrieval_fused_min_rows,
          args.retrieval_shards, args.retrieval_nprobe)


if __name__ == "__main__":
    main()
