"""Serving launcher: batched-request decode loop with optional MCAM
retrieval fusion (reduced configs run on CPU; the dry-run lowers the same
serve_step for the production meshes).

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b --smoke \
        --batch 4 --steps 16 [--retrieval]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import load_config
from repro.launch import steps as steps_lib
from repro.models import transformer as tfm
from repro.models.sharding import Rules


def serve(arch: str, smoke: bool, batch: int, steps: int, prompt_len: int,
          retrieval: bool = False, retrieval_mode: str = "two-phase",
          retrieval_backend: str = "auto", retrieval_k: int = 32,
          retrieval_fused_min_rows: int | None = None):
    cfg = load_config(arch, smoke=smoke)
    rules = Rules(batch=(), fsdp=(), tensor=(), expert=())
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    max_seq = prompt_len + steps
    caches = tfm.init_cache(cfg, batch, max_seq)
    step_fn = jax.jit(steps_lib.make_serve_step(cfg, rules))

    mstate = mem_cfg = None
    if retrieval:
        from repro.core.avss import SearchConfig
        from repro.core.memory import MemoryConfig
        from repro.engine import MemoryStore, RetrievalEngine
        mem_cfg = MemoryConfig(capacity=1024, dim=min(48, cfg.d_model),
                               search=SearchConfig("mtmc", cl=8, mode="avss",
                                                   use_kernel="ref"))
        vecs = jax.random.normal(jax.random.PRNGKey(7), (256, mem_cfg.dim))
        toks = jax.random.randint(jax.random.PRNGKey(8), (256,), 0,
                                  cfg.vocab_size)
        # program once at write time (values + proj + s_grid); the decode
        # loop below jits against the store's constant layouts
        mstate = MemoryStore.create(mem_cfg).calibrate(vecs).write(vecs, toks)
        # fused-threshold override (e.g. a TPU-measured dense-vs-fused
        # crossover) applies engine-wide without a code change
        eng_kw = {} if retrieval_fused_min_rows is None else \
            {"fused_min_rows": retrieval_fused_min_rows}
        engine = (RetrievalEngine(mem_cfg.search, backend=retrieval_backend,
                                  **eng_kw)
                  if retrieval_mode in ("two-phase", "ideal") else None)
        mode = "ideal" if retrieval_mode == "ideal" else "two_phase"
        step_fn = jax.jit(steps_lib.make_serve_step_with_mcam(
            cfg, rules, mem_cfg, engine=engine, k=retrieval_k, mode=mode))

    key = jax.random.PRNGKey(1)
    tok = jax.random.randint(key, (batch, 1), 0, cfg.vocab_size)
    for t in range(prompt_len):  # warm the cache with a random prompt
        args = (params, caches, {"tokens": tok}, jnp.int32(t))
        out = step_fn(*args, mstate) if retrieval else step_fn(*args)
        logits, caches = out
        tok = jax.random.randint(jax.random.fold_in(key, t), (batch, 1), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    toks = []
    for i in range(steps):
        args = (params, caches, {"tokens": tok}, jnp.int32(prompt_len + i))
        out = step_fn(*args, mstate) if retrieval else step_fn(*args)
        logits, caches = out
        tok = jnp.argmax(logits[:, 0], -1)[:, None]
        toks.append(np.asarray(tok))
    dt = time.time() - t0
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(f"{arch}: {steps} steps x {batch} reqs in {dt:.2f}s "
          f"({steps * batch / dt:.1f} tok/s)")
    return np.concatenate(toks, 1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--retrieval", action="store_true")
    ap.add_argument("--retrieval-mode", default="two-phase",
                    choices=["dense", "two-phase", "ideal"],
                    help="dense: softmax over the whole store (legacy "
                         "comparison path); two-phase: engine shortlist + "
                         "exact noisy rescore; ideal: engine top-k by exact "
                         "digital distance only (cheapest; streams through "
                         "the fused shortlist kernel at large N)")
    ap.add_argument("--retrieval-backend", default="auto",
                    choices=["auto", "ref", "pallas", "mxu", "fused"])
    ap.add_argument("--retrieval-k", type=int, default=32)
    ap.add_argument("--retrieval-fused-min-rows", type=int, default=None,
                    help="override the fused-shortlist row threshold "
                         "(engine.IDEAL_FUSED_MIN_ROWS default; applies "
                         "per shard-local block on sharded stores) -- a "
                         "perf knob, results are bit-identical either way")
    args = ap.parse_args(argv)
    serve(args.arch, args.smoke, args.batch, args.steps, args.prompt_len,
          args.retrieval, args.retrieval_mode, args.retrieval_backend,
          args.retrieval_k, args.retrieval_fused_min_rows)


if __name__ == "__main__":
    main()
