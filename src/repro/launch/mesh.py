"""Production mesh builders.

Functions (never module-level constants) so importing this module touches no
jax device state -- the dry-run must set XLA_FLAGS before first jax init.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single pod; (2, 16, 16) pod x data x model for
    the 2-pod = 512-chip deployment."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} -- set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (see launch/dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host has (tests/examples): (n/mp, mp) data x model."""
    devices = jax.devices()
    n = len(devices)
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"),
                         devices=devices[: (n // mp) * mp])
