"""Step builders: jitted/shardable train, prefill and serve steps + the
ShapeDtypeStruct `input_specs` used by the multi-pod dry-run.

Sharding summary (logical axes resolved by repro.models.sharding.Rules and
LEGALIZED against actual dims -- indivisible axes shift right or drop):

  params      name-based specs (layers.PARAM_LOGICAL); FSDP rows over
              ("pod","data"), tensor columns over "model", experts over
              "model" with FSDP'd expert FFN width.
  opt state   inherits the tracked param's sharding (ZeRO); 8-bit block
              states shard their block axis over the FSDP axes.
  batch       (accum, microbatch, ...) with microbatch over ("pod","data").
  kv caches   batch over DP; heads over "model" when divisible, else
              head_dim; for batch=1 long-context the DP axes legalize onto
              the sequence axis => sequence-parallel cache (SP).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import transformer as tfm
from repro.models.sharding import Rules, rules_for_mesh
from repro.optim import clip_by_global_norm, make_optimizer
from repro.runtime.compression import with_error_feedback

# --------------------------------------------------------------------------
# Config adaptation per (arch x shape).
# --------------------------------------------------------------------------

# microbatch sizes for train_4k (global batch 256) chosen from the HBM model
# in DESIGN.md / EXPERIMENTS.md Sec. Dry-run
TRAIN_MICROBATCH = {
    "llama3-405b": 64,
    "deepseek-v3-671b": 64,
    "qwen1.5-110b": 128,
    "command-r-plus-104b": 128,
}

# optimizer choice at scale (moment memory -- see optim/optimizers.py)
ARCH_OPTIMIZER = {
    "llama3-405b": ("adamw", {"state_dtype": jnp.bfloat16}),
    "qwen1.5-110b": ("adamw", {"state_dtype": jnp.bfloat16}),
    "command-r-plus-104b": ("adamw", {"state_dtype": jnp.bfloat16}),
    "deepseek-v3-671b": ("adafactor", {}),
}


def decode_rules(mesh) -> Rules:
    """Perf iteration 3 (REPRO_OPT>=3, EXPERIMENTS.md §Perf): serving rules.

    Decode activations are tiny (B x 1 x D); sharding their batch over the
    data axis CONFLICTS with the FSDP row sharding of the weights on that
    same axis, so the partitioner all-gathers every layer's weights every
    token (~50 GB/step on llama3-405b). Replicating decode activations over
    DP removes the conflict (weights stay put, partial-sum ARs are KBs);
    the KV cache shards its sequence axis over ALL chips (ring-attention
    layout: softmax stats cross chips, the cache never does).
    """
    names = mesh.axis_names
    dp = ("pod", "data") if "pod" in names else ("data",)
    return Rules(batch=(), fsdp=dp, tensor=("model",), expert=("model",),
                 seq=dp + ("model",))


import os as _os

OPT_LEVEL = int(_os.environ.get("REPRO_OPT", "0") or 0)


def rules_for(mesh, shape: ShapeConfig) -> Rules:
    from repro.models.sharding import rules_for_mesh
    if shape.kind == "decode" and OPT_LEVEL >= 3:
        return decode_rules(mesh)
    return rules_for_mesh(mesh)


def adapt_config(cfg: ModelConfig, shape: ShapeConfig, dp: int) -> ModelConfig:
    """Resolve execution knobs that depend on the deployment."""
    upd = {}
    if cfg.moe is not None:
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        g = max(dp, tokens // 1024)
        g = (g // dp) * dp or dp
        upd["moe"] = dataclasses.replace(cfg.moe, groups=g)
    if shape.kind != "train":
        upd["remat"] = False
    if shape.seq_len >= 16384 and cfg.attn_chunk:
        upd["attn_chunk"] = 2048
    if OPT_LEVEL >= 6 and shape.kind == "train" and shape.seq_len <= 4096:
        # Perf iteration 6: at 4k the full (S, S) score tile fits per-device
        # HBM; the online-softmax chunk scan re-reads the q block and
        # rescales the accumulator per chunk, costing extra HBM passes.
        upd["attn_chunk"] = 0
    return dataclasses.replace(cfg, **upd) if upd else cfg


def microbatch_for(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if shape.kind != "train":
        return shape.global_batch
    if shape.microbatch:
        return shape.microbatch
    return TRAIN_MICROBATCH.get(cfg.name, shape.global_batch)


def optimizer_for(cfg: ModelConfig, tc: TrainConfig):
    name, kw = ARCH_OPTIMIZER.get(cfg.name, (tc.optimizer, {}))
    return make_optimizer(name, tc.learning_rate, **kw)


# --------------------------------------------------------------------------
# Sharding trees.
# --------------------------------------------------------------------------


def param_shardings(cfg: ModelConfig, mesh, rules: Rules):
    return tfm.shardings(cfg, mesh, rules)


def opt_shardings(opt_shapes, params_abs, p_shardings, mesh, rules: Rules):
    """Moments with the param's shape inherit its sharding; blocked 8-bit
    states shard dim0 over FSDP; scalars replicate."""
    by_shape = {}
    for p, s in zip(jax.tree_util.tree_leaves(params_abs),
                    jax.tree_util.tree_leaves(p_shardings)):
        by_shape[p.shape] = s

    rep = NamedSharding(mesh, P())
    fsdp = rules.resolve("fsdp")[0]

    def mk(leaf):
        if leaf.shape in by_shape and len(leaf.shape):
            return by_shape[leaf.shape]
        if leaf.ndim >= 1:
            spec = tfm._legalize(P(fsdp), leaf.shape, mesh)
            return NamedSharding(mesh, spec)
        return rep

    return jax.tree_util.tree_map(mk, opt_shapes)


_CACHE_LOGICAL = {
    "k": (None, "batch", None, "tensor", None),
    "v": (None, "batch", None, "tensor", None),
    "kpos": (),
    "ckv": (None, "batch", None, "tensor"),
    "krope": (None, "batch", None, None),
    "C": (None, "batch", "tensor", None, None),
    "n": (None, "batch", "tensor", None),
    "m": (None, "batch", None),
    "h": (None, "batch", "tensor", None),
    "c": (None, "batch", "tensor", None),
    "conv": (None, "batch", None, "tensor"),
}


def cache_shardings(cfg: ModelConfig, batch: int, max_seq: int, mesh,
                    rules: Rules):
    """KV caches: batch over DP; the model axis goes to KV heads when
    divisible, OTHERWISE to the sequence axis (sequence-parallel cache: the
    attention contraction then reduces tiny softmax stats instead of
    gathering the cache -- the ring-attention layout)."""
    import numpy as np
    tensor_size = int(np.prod([mesh.shape[a] for a in rules.tensor])) \
        if rules.tensor else 1
    seq_size = int(np.prod([mesh.shape[a] for a in rules.seq])) \
        if rules.seq else 0
    cache_abs = jax.eval_shape(lambda: tfm.init_cache(cfg, batch, max_seq))
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abs)
    out = []
    for path, leaf in flat:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if (name in ("k", "v", "ckv") and leaf.ndim >= 4 and seq_size
                and leaf.shape[2] % seq_size == 0):
            # serving layout: sequence sharded over every mesh axis
            logical = (None, None, "seq", None, None)[:leaf.ndim]
        elif name in ("k", "v", "ckv") and leaf.ndim >= 4:
            # (Lg, B, T, KV[, hd]) / (Lg, B, T, r)
            kv_dim = 3 if name in ("k", "v") else 3
            kv_ok = leaf.shape[kv_dim] % tensor_size == 0 \
                if name in ("k", "v") else leaf.shape[3] % tensor_size == 0
            if kv_ok:
                logical = (None, "batch", None, "tensor", None)[:leaf.ndim]
            elif leaf.shape[2] % tensor_size == 0:
                logical = (None, "batch", "tensor", None, None)[:leaf.ndim]
            else:
                logical = (None, "batch", None, None, None)[:leaf.ndim]
        else:
            logical = _CACHE_LOGICAL.get(name, ())
            logical = logical[:leaf.ndim]
            logical = (None,) * (leaf.ndim - len(logical)) + tuple(logical)
        spec = rules.resolve(*logical)
        spec = tfm._legalize(spec, leaf.shape, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out), cache_abs


# --------------------------------------------------------------------------
# Input specs (dry-run contract: weak-type-correct, shardable, no alloc).
# --------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules):
    """ShapeDtypeStruct stand-ins (with shardings) for every model input of
    the step that `shape` lowers."""
    # helper building a struct with a legalized sharding for ITS shape
    def struct(shp, dtype, logical):
        spec = rules.resolve(*logical)
        spec = tfm._legalize(spec, shp, mesh)
        return jax.ShapeDtypeStruct(shp, dtype,
                                    sharding=NamedSharding(mesh, spec))

    S = shape.seq_len
    if shape.kind == "train":
        mb = microbatch_for(cfg, shape)
        accum = shape.global_batch // mb
        lead = (accum, mb)
        llog = (None, "batch")
    else:
        lead = (shape.global_batch,)
        llog = ("batch",)

    batch = {}
    seq = S if shape.kind != "decode" else 1
    if cfg.input_mode == "tokens":
        batch["tokens"] = struct(lead + (seq,), jnp.int32, llog + (None,))
    else:
        batch["embeddings"] = struct(lead + (seq, cfg.d_model), jnp.bfloat16,
                                     llog + (None, None))
        if cfg.rope_type == "mrope":
            batch["positions3"] = struct(lead + (seq, 3), jnp.int32,
                                         llog + (None, None))
    if shape.kind == "train":
        batch["labels"] = struct(lead + (seq,), jnp.int32, llog + (None,))
    return batch


# --------------------------------------------------------------------------
# Steps.
# --------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, tc: TrainConfig, rules: Rules,
                    unroll_accum: bool = False):
    """(params, opt_state, batch) -> (params, opt_state, metrics).
    batch leaves carry a leading accumulation axis. unroll_accum unrolls the
    accumulation loop (dry-run cost calibration)."""
    optimizer = optimizer_for(cfg, tc)

    def train_step(params, opt_state, batch):
        accum = jax.tree_util.tree_leaves(batch)[0].shape[0]

        def micro(carry, mb):
            gsum, lsum = carry
            (loss, _), grads = jax.value_and_grad(
                tfm.loss_fn, has_aux=True)(params, cfg, mb, rules)
            gsum = jax.tree_util.tree_map(jnp.add, gsum, grads)
            return (gsum, lsum + loss), None

        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        carry = (zeros, jnp.zeros(()))
        if unroll_accum:
            for i in range(accum):
                mb = jax.tree_util.tree_map(lambda a: a[i], batch)
                carry, _ = micro(carry, mb)
            gsum, lsum = carry
        else:
            (gsum, lsum), _ = jax.lax.scan(micro, carry, batch)
        grads = jax.tree_util.tree_map(lambda g: g / accum, gsum)
        loss = lsum / accum

        if tc.grad_compression == "int8":
            grads, ef = with_error_feedback(grads,
                                            opt_state.get("ef_residual"))
            opt_state = {**opt_state, "ef_residual": ef}

        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        inner = {k: v for k, v in opt_state.items() if k != "ef_residual"}
        updates, inner_new = optimizer.update(grads, inner, params)
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p + u).astype(p.dtype), params, updates)
        # in-step anomaly guard: non-finite => keep old params (skip)
        ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        new_params = jax.tree_util.tree_map(
            lambda n, o: jnp.where(ok, n, o), new_params, params)
        new_inner = jax.tree_util.tree_map(
            lambda n, o: jnp.where(ok, n, o), inner_new, inner)
        out_state = {**opt_state, **new_inner}
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "applied": ok.astype(jnp.float32)}
        return new_params, out_state, metrics

    return train_step, optimizer


def make_hat_train_steps(apply_fn, hat_cfg, pre_optimizer,
                         meta_optimizer=None, *, n_way: int,
                         mesh=None, data_axes=("data",)):
    """Two-stage hardware-aware trainer steps (paper Sec. 3.3).

    Stage 1 (`pretrain_step`): controller + linear head, plain CE over the
    full training class set. Stage 2 (`meta_step`): episodic CE THROUGH the
    simulated MCAM -- `repro.core.hat.meta_loss`, whose forward is the
    engine's own differentiable episodic path
    (`RetrievalEngine.episode_votes`), so the trained controller serves
    bit-identically through `MemoryStore` + `engine.search`.

    Data parallelism follows the launch-layer idiom (same as
    `make_train_step`): the steps are jitted and the returned `place(tree)`
    helper row-shards batch/episode leaves over the mesh's `data_axes`
    (leading dim divisible by the shard count; everything else, params
    included, replicates) -- the partitioner then runs the embedding
    forward data-parallel and the episodic quantization statistics as
    global collectives, with unchanged semantics.

    apply_fn:       (backbone_params, images) -> embeddings.
    hat_cfg:        repro.core.hat.HATConfig.
    pre_optimizer / meta_optimizer: (init, update) optimizers from
                    repro.optim; meta defaults to the pretrain one.
    n_way:          episode way count (static: kept out of the traced tree).
    Returns (pretrain_step, meta_step, place).

    >>> import jax, jax.numpy as jnp
    >>> from repro.core.avss import SearchConfig
    >>> from repro.core.hat import HATConfig
    >>> from repro.launch.steps import make_hat_train_steps
    >>> from repro.optim import adamw
    >>> hat = HATConfig(search=SearchConfig("mtmc", cl=2, mode="avss",
    ...                                     use_kernel="ref"))
    >>> apply_fn = lambda p, x: jax.nn.relu(x @ p["w"])
    >>> opt = adamw(1e-2)
    >>> pre, meta, place = make_hat_train_steps(apply_fn, hat, opt, n_way=2)
    >>> params = {"backbone": {"w": jnp.eye(4)}}
    >>> ep = {"support_images": jnp.eye(4),
    ...       "support_labels": jnp.array([0, 1, 0, 1]),
    ...       "query_images": jnp.eye(4)[:2],
    ...       "query_labels": jnp.array([0, 1])}
    >>> p2, s2, loss = meta(params, opt.init(params), place(ep),
    ...                     jax.random.PRNGKey(0))
    >>> bool(jnp.isfinite(loss))
    True
    """
    from repro.core import hat as hat_lib
    if meta_optimizer is None:
        meta_optimizer = pre_optimizer

    def _apply(params, opt_state, grads, optimizer):
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
        return params, opt_state

    @jax.jit
    def pretrain_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(hat_lib.pretrain_loss)(
            params, batch, apply_fn)
        params, opt_state = _apply(params, opt_state, grads, pre_optimizer)
        return params, opt_state, loss

    @jax.jit
    def meta_step(params, opt_state, ep_arrays, key):
        episode = {**ep_arrays, "n_way": n_way}      # n_way stays static
        loss, grads = jax.value_and_grad(hat_lib.meta_loss)(
            params, episode, apply_fn, hat_cfg, key)
        params, opt_state = _apply(params, opt_state, grads, meta_optimizer)
        return params, opt_state, loss

    def place(tree):
        """Row-shard batch leaves over the data axes; replicate the rest."""
        if mesh is None:
            return tree
        shards = int(np.prod([mesh.shape[a] for a in data_axes]))
        row = NamedSharding(mesh, P(data_axes))
        rep = NamedSharding(mesh, P())
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(
                jnp.asarray(x),
                row if (jnp.ndim(x) and jnp.shape(x)[0] % shards == 0)
                else rep),
            tree)

    return pretrain_step, meta_step, place


def make_prefill_step(cfg: ModelConfig, rules: Rules):
    def prefill_step(params, batch):
        logits, aux, caches = tfm.forward(params, cfg, batch, rules,
                                          return_cache=True, last_only=True)
        return logits, caches
    return prefill_step


def make_serve_step(cfg: ModelConfig, rules: Rules):
    def serve_step(params, caches, batch, pos):
        logits, caches = tfm.decode_step(params, cfg, batch, caches, pos,
                                         rules)
        return logits, caches
    return serve_step


def make_serve_step_with_mcam(cfg: ModelConfig, rules: Rules, mem_cfg,
                              lam: float = 0.3, engine=None, k: int = 32,
                              mode: str = "two_phase",
                              nprobe: int | None = None):
    """Paper-integrated serving: the decoded hidden state queries the MCAM
    memory and the vote distribution over memory labels (token ids) mixes
    with the LM softmax -- a kNN-LM head served from the simulated NAND-CAM.

    The memory argument is a `repro.engine.MemoryStore` (registered pytree):
    its write-time `proj` / `s_grid` layouts are jit constants of the decode
    loop, so no step re-runs `layout_support` or `support_projection`.

    engine=None (default): dense ideal-distance softmax over the whole
    LUT-projected store (one bf16 matmul, rows sharded over the mesh) --
    the legacy comparison path; it materialises the (B, N) distance matrix.
    engine=RetrievalEngine: retrieval through the unified
    `engine.search(store, q, SearchRequest)` with `mode`:
      'two_phase'  MXU shortlist + exact noisy vote rescore; the mixture
                   weights come from the NOISY MCAM VOTES, so the served
                   distribution reflects the simulated hardware's
                   similarity judgement, not the ideal distance.
      'ideal'      top-k by exact digital distance only (votes == -dist on
                   valid candidates) -- the cheapest serving path; at
                   N >= engine.IDEAL_FUSED_MIN_ROWS it streams through the
                   fused shortlist kernel instead of the dense matmul.
    nprobe: shards visited per query when the store is partitioned
    (`MemoryStore.shard`); nprobe < n_shards engages the phase-0 router
    (repro/engine/router.py) -- bit-identical to brute force restricted to
    the visited shards; None keeps the exhaustive search."""
    from repro.engine import SearchRequest
    request = SearchRequest(mode=mode, k=k, nprobe=nprobe)

    def serve_step(params, caches, batch, pos, store):
        logits, caches, hidden = tfm.decode_step(
            params, cfg, batch, caches, pos, rules, return_hidden=True)
        q = hidden[:, 0][:, :mem_cfg.dim]                     # (B, dim)
        if engine is None:
            from repro.kernels import ops as kops
            # ideal AVSS digital distance: one bf16 matmul against the
            # LUT-projected store (rows sharded over the whole mesh)
            q1h = kops.query_onehot(store.quantize_queries(q), jnp.float32)
            dist = q1h @ store.proj.astype(jnp.float32).T     # (B, N)
            w = jax.nn.softmax(-dist / 10.0, axis=-1)
            onehot = jax.nn.one_hot(store.labels, cfg.vocab_size,
                                    dtype=w.dtype)
            p_mem = w @ onehot                                # (B, V)
        else:
            res = engine.search(store, q, request)
            valid = res.labels >= 0                           # (B, k)
            # weight by the exact noisy votes (higher = more similar); the
            # -1e30 fill + post-mask keeps an all-invalid row (store
            # sparser than k) a harmless zero contribution instead of NaN
            w = jax.nn.softmax(
                jnp.where(valid, res.votes / 10.0, -1e30), axis=-1)
            w = w * valid
            labels = jnp.where(valid, res.labels, 0)
            onehot = jax.nn.one_hot(labels, cfg.vocab_size, dtype=w.dtype)
            p_mem = jnp.einsum("bk,bkv->bv", w, onehot)       # (B, V)
        p_lm = jax.nn.softmax(logits[:, 0], axis=-1)
        mixed = jnp.log((1 - lam) * p_lm + lam * p_mem + 1e-20)
        return mixed[:, None], caches

    return serve_step
