import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^^ MUST precede every other import (jax locks the device count on first
# init). The 512 placeholder CPU devices exist ONLY for this dry-run process.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch llama3-405b --shape train_4k --mesh single [--retrieval] \
        [--out results.json]

Succeeding here proves the distribution config is coherent: shardings
legalize, the SPMD partitioner finds a schedule, per-device buffers are
bounded, and the collective set is what DESIGN.md claims. Output JSON:
  flops / bytes from compiled.cost_analysis(),
  per-collective byte totals parsed from the partitioned HLO,
  memory_analysis (argument/output/temp/peak bytes per device),
  roofline terms vs TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI).
"""

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import cost as cost_lib
from repro.configs import SHAPES, load_config, supports_shape
from repro.configs.base import TrainConfig
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.models.sharding import active_mesh

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

# Cost/HLO extraction is the analysis package's cost model
# (repro/analysis/cost.py, ONE spelling for the whole repo); these
# aliases keep dryrun's long-standing surface (tests import them here).
_COLLECTIVES = cost_lib.COLLECTIVE_KINDS
_shape_bytes = cost_lib.shape_bytes
parse_collectives = cost_lib.parse_collectives


def _tree_bytes_per_device(tree) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        bpe = jnp.dtype(leaf.dtype).itemsize
        shard = leaf.sharding
        nshards = getattr(shard, "num_devices", 1)
        if hasattr(shard, "shard_shape"):
            n = int(np.prod(shard.shard_shape(leaf.shape))) if leaf.shape else 1
        total += n * bpe
    return total


def _with_shardings(abs_tree, shard_tree):
    return jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abs_tree, shard_tree)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense train) / 2*N*D (inference), N = active
    params (excluding embeddings), D = tokens processed."""
    aps = tfm.abstract_params(cfg)
    total = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(aps))
    embed = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2) \
        if cfg.input_mode == "tokens" or not cfg.tie_embeddings else 0
    n_params = total - embed
    if cfg.moe is not None:
        m = cfg.moe
        layers_moe = sum(cfg.moe_layers())
        expert_p = m.n_routed * 3 * cfg.d_model * m.d_ff * layers_moe
        active_p = (m.top_k / m.n_routed) * expert_p
        n_params = n_params - expert_p + active_p
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n_params * tokens


def _compile_step(cfg, shape, mesh, rules, tc, retrieval, unroll=False):
    """Lower + compile the step `shape` dictates. Returns (compiled,
    state_bytes_per_device)."""
    p_shard = steps_lib.param_shardings(cfg, mesh, rules)
    params_abs = tfm.abstract_params(cfg)
    params_in = _with_shardings(params_abs, p_shard)
    batch_in = steps_lib.input_specs(cfg, shape, mesh, rules)

    with mesh, active_mesh(mesh, rules):
        if shape.kind == "train":
            step, optimizer = steps_lib.make_train_step(
                cfg, tc, rules, unroll_accum=unroll)
            opt_abs = jax.eval_shape(optimizer.init, params_abs)
            opt_shard = steps_lib.opt_shardings(opt_abs, params_abs, p_shard,
                                                mesh, rules)
            opt_in = _with_shardings(opt_abs, opt_shard)
            lowered = jax.jit(step).lower(params_in, opt_in, batch_in)
            state_bytes = (_tree_bytes_per_device(params_in)
                           + _tree_bytes_per_device(opt_in))
        elif shape.kind == "prefill":
            step = steps_lib.make_prefill_step(cfg, rules)
            lowered = jax.jit(step).lower(params_in, batch_in)
            state_bytes = _tree_bytes_per_device(params_in)
        else:  # decode
            c_shard, cache_abs = steps_lib.cache_shardings(
                cfg, shape.global_batch, shape.seq_len, mesh, rules)
            cache_in = _with_shardings(cache_abs, c_shard)
            pos_in = jax.ShapeDtypeStruct((), jnp.int32)
            if retrieval:
                from repro.core.memory import MemoryConfig
                from repro.engine import MemoryStore
                from jax.sharding import NamedSharding
                from jax.sharding import PartitionSpec as P
                mem_cfg = MemoryConfig(capacity=131072, dim=48)
                # calibrate the abstract store: the serving store is always
                # calibrated before decode, and quantize_queries refuses
                # float queries on a never-calibrated store
                mem_abs = jax.eval_shape(
                    lambda: MemoryStore.create(mem_cfg).calibrate(
                        jnp.zeros((4, mem_cfg.dim), jnp.float32)))
                row = NamedSharding(mesh, P(tuple(mesh.axis_names)))
                rep = NamedSharding(mesh, P())
                mem_shard = jax.tree_util.tree_map(
                    lambda v: (row if getattr(v, "ndim", 0) >= 1 else rep),
                    mem_abs)
                mem_in = _with_shardings(mem_abs, mem_shard)
                step = steps_lib.make_serve_step_with_mcam(cfg, rules,
                                                           mem_cfg)
                lowered = jax.jit(step).lower(params_in, cache_in, batch_in,
                                              pos_in, mem_in)
            else:
                step = steps_lib.make_serve_step(cfg, rules)
                lowered = jax.jit(step).lower(params_in, cache_in, batch_in,
                                              pos_in)
            state_bytes = (_tree_bytes_per_device(params_in)
                           + _tree_bytes_per_device(cache_in))
        compiled = lowered.compile()
    return compiled, int(state_bytes)


# per-device flops/bytes + per-collective byte totals (UNcorrected: scan
# bodies counted once -- see _corrected_metrics)
_metrics = cost_lib.roofline_metrics


def _corrected_metrics(cfg, shape, mesh, rules, tc, retrieval) -> dict:
    """Trip-count-corrected totals. XLA's cost_analysis counts each
    while-loop (lax.scan) body ONCE; the real step executes the layer-scan
    body L_g times inside an accumulation scan of A steps. This builds the
    compiled count variants (M1 / M2_g / M3); the finite-difference
    recovery of true totals is repro.analysis.cost.scan_trip_count_totals
    (the formula is documented there)."""
    groups = [list(g) for g in cfg.layer_groups()]
    mb = steps_lib.microbatch_for(cfg, shape)
    accum = (shape.global_batch // mb) if shape.kind == "train" else 1

    def variant(counts, accum_n):
        vcfg = dataclasses.replace(
            cfg, scan_layers=False, layer_groups_override=tuple(
                (t, m, c) for (t, m, _), c in zip(groups, counts)))
        vshape = dataclasses.replace(
            shape, global_batch=(mb * accum_n if shape.kind == "train"
                                 else shape.global_batch),
            microbatch=(mb if shape.kind == "train" else 0))
        compiled, _ = _compile_step(vcfg, vshape, mesh, rules, tc, retrieval,
                                    unroll=True)
        return _metrics(compiled)

    ones = [1] * len(groups)
    m1 = variant(ones, 1)
    m2_groups = []
    for gi in range(len(groups)):
        counts = list(ones)
        counts[gi] = 2
        m2_groups.append(variant(counts, 1))
    m3 = variant(ones, 2) if shape.kind == "train" and accum > 1 else None
    layer_counts = [c for (_, _, c) in cfg.layer_groups()]
    return cost_lib.scan_trip_count_totals(m1, m2_groups, layer_counts,
                                           accum, m3=m3)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             retrieval: bool = False, calibrate: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    shape = SHAPES[shape_name]
    rules = steps_lib.rules_for(mesh, shape)  # REPRO_OPT>=3: serving rules
    cfg = load_config(arch)
    ok, why = supports_shape(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        return {**rec, "status": "skipped", "reason": why}

    dp = int(np.prod([mesh.shape[a] for a in rules.batch]))
    cfg = steps_lib.adapt_config(cfg, shape, dp)
    tc = TrainConfig()

    # 1. the deliverable: the FULL cell must lower + compile
    t0 = time.time()
    compiled, state_bytes = _compile_step(cfg, shape, mesh, rules, tc,
                                          retrieval)
    compile_s = time.time() - t0
    mem = cost_lib.compiled_memory(compiled)
    raw = _metrics(compiled)

    # 2. trip-count-corrected roofline terms
    corr = _corrected_metrics(cfg, shape, mesh, rules, tc, retrieval) \
        if calibrate else raw

    flops = corr["flops"]
    bytes_acc = corr["bytes"]
    coll_bytes = corr["coll_total"]
    mf = model_flops(cfg, shape)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = coll_bytes / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return {
        **rec, "status": "ok", "chips": n_chips,
        "compile_s": round(compile_s, 1),
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll_bytes,
        "collectives_corrected": {k: corr[f"coll_{k}"] for k in _COLLECTIVES},
        "raw_uncorrected": raw,
        "memory_analysis": mem,
        "state_bytes_per_device": int(state_bytes),
        "model_flops_total": mf,
        "useful_flops_ratio": (mf / (flops * n_chips)) if flops else None,
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dominant,
            "bound_s": max(compute_s, memory_s, collective_s),
        },
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--retrieval", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    rec = run_cell(args.arch, args.shape, args.mesh == "multi",
                   retrieval=args.retrieval)
    js = json.dumps(rec, indent=1)
    print(js)
    if args.out:
        with open(args.out, "w") as f:
            f.write(js)
    if rec["status"] == "ok":
        print(f"\nMEMORY: {rec['memory_analysis']}", file=sys.stderr)
        print(f"COST: flops/dev={rec['flops_per_device']:.3e} "
              f"bytes/dev={rec['bytes_per_device']:.3e}", file=sys.stderr)
    return 0 if rec["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
