"""Training launcher (CPU-runnable for reduced configs; the same code path
the dry-run lowers for the production meshes).

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b --smoke \
        --steps 50 --batch 8 --seq 128

Fault tolerance wired in: CheckpointManager (async, atomic, elastic),
PreemptionHandler (SIGTERM => final checkpoint), AnomalyDetector (NaN /
grad-spike step skipping -- also enforced inside the jitted step),
StepWatchdog (straggler signal), deterministic step-addressable data
(restart-consistent).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, load_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data.lm import LMDataConfig, SyntheticLM, embedding_batch_for_step
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tfm
from repro.models.sharding import active_mesh, rules_for_mesh
from repro.runtime.ft import (AnomalyDetector, PreemptionHandler,
                              StepWatchdog)


def make_batch(cfg, shape, data, step, accum, mb):
    if cfg.input_mode == "tokens":
        b = data.batch_for_step(step)
    else:
        b = embedding_batch_for_step(step, shape.global_batch, shape.seq_len,
                                     cfg.d_model, cfg.vocab_size,
                                     mrope=cfg.rope_type == "mrope")
    return {k: np.asarray(v).reshape((accum, mb) + v.shape[1:])
            for k, v in b.items()}


def train(arch: str, smoke: bool, steps: int, batch: int, seq: int,
          ckpt_dir: str, resume: bool = False, model_parallel: int = 1,
          log_every: int = 10):
    cfg = load_config(arch, smoke=smoke)
    shape = ShapeConfig("custom", seq, batch, "train")
    tc = TrainConfig(total_steps=steps, checkpoint_dir=ckpt_dir,
                     learning_rate=1e-3 if smoke else 3e-4)
    mesh = make_host_mesh(model_parallel)
    rules = rules_for_mesh(mesh)
    dp = int(np.prod([mesh.shape[a] for a in rules.batch]))
    cfg = steps_lib.adapt_config(cfg, shape, dp)
    mb = steps_lib.microbatch_for(cfg, shape)
    accum = shape.global_batch // mb

    data = SyntheticLM(LMDataConfig(seq, batch, cfg.vocab_size))
    with mesh, active_mesh(mesh, rules):
        step_fn, optimizer = steps_lib.make_train_step(cfg, tc, rules)
        params = tfm.init(jax.random.PRNGKey(tc.seed), cfg)
        opt_state = optimizer.init(params)
        jstep = jax.jit(step_fn, donate_argnums=(0, 1))

        mgr = CheckpointManager(ckpt_dir, every=tc.checkpoint_every)
        start = 0
        if resume and mgr.latest_step() is not None:
            start = mgr.latest_step()
            state = mgr.restore({"params": params, "opt": opt_state,
                                 "step": jnp.zeros((), jnp.int32)})
            params, opt_state = state["params"], state["opt"]
            print(f"resumed from step {start}")

        pre = PreemptionHandler()
        anom = AnomalyDetector()
        dog = StepWatchdog()
        losses = []
        for step in range(start, steps):
            dog.start()
            b = make_batch(cfg, shape, data, step, accum, mb)
            params, opt_state, metrics = jstep(params, opt_state, b)
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            dt = dog.stop()
            losses.append(loss)
            if not anom.check(loss, gn):
                print(f"step {step}: ANOMALY skipped (loss={loss}, gn={gn})")
            if step % log_every == 0 or step == steps - 1:
                print(f"step {step:5d} loss {loss:.4f} gnorm {gn:.3f} "
                      f"{dt*1000:.0f}ms")
            mgr.maybe_save(step + 1, {"params": params, "opt": opt_state,
                                      "step": jnp.int32(step + 1)})
            if pre.preempted:
                print("preemption requested -> checkpoint + exit")
                mgr.maybe_save(step + 1,
                               {"params": params, "opt": opt_state,
                                "step": jnp.int32(step + 1)}, force=True)
                break
        mgr.wait()
    return losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)
    losses = train(args.arch, args.smoke, args.steps, args.batch, args.seq,
                   args.ckpt_dir, args.resume, args.model_parallel)
    print(f"first-10 mean {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean {np.mean(losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
