"""Training launcher (CPU-runnable for reduced configs; the same code path
the dry-run lowers for the production meshes).

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b --smoke \
        --steps 50 --batch 8 --seq 128

Fault tolerance wired in: CheckpointManager (async, atomic, elastic),
PreemptionHandler (SIGTERM => final checkpoint), AnomalyDetector (NaN /
grad-spike step skipping -- also enforced inside the jitted step),
StepWatchdog (straggler signal), deterministic step-addressable data
(restart-consistent).

Hardware-aware training (paper Sec. 3.3) is a first-class launch target:

    PYTHONPATH=src python -m repro.launch.train --hat \
        --hat-pretrain-steps 40 --hat-meta-steps 40 --hat-n-way 6

runs the two-stage HAT flow (controller pretrain -> episodic meta-train
THROUGH the engine's differentiable MCAM forward), then CLOSES THE LOOP:
the trained controller's support embeddings are calibrated + written into
a `MemoryStore`, served through `engine.search`, and the served per-class
scores are checked bit-identical to the in-training evaluation (the
train/serve parity contract). Controller params and the programmed store
are checkpointed under --ckpt-dir for a separate serving process.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import load_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data.lm import LMDataConfig, SyntheticLM, embedding_batch_for_step
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tfm
from repro.models.sharding import active_mesh, rules_for_mesh
from repro.runtime.ft import (AnomalyDetector, PreemptionHandler,
                              StepWatchdog)


def make_batch(cfg, shape, data, step, accum, mb):
    if cfg.input_mode == "tokens":
        b = data.batch_for_step(step)
    else:
        b = embedding_batch_for_step(step, shape.global_batch, shape.seq_len,
                                     cfg.d_model, cfg.vocab_size,
                                     mrope=cfg.rope_type == "mrope")
    return {k: np.asarray(v).reshape((accum, mb) + v.shape[1:])
            for k, v in b.items()}


def train(arch: str, smoke: bool, steps: int, batch: int, seq: int,
          ckpt_dir: str, resume: bool = False, model_parallel: int = 1,
          log_every: int = 10):
    cfg = load_config(arch, smoke=smoke)
    shape = ShapeConfig("custom", seq, batch, "train")
    tc = TrainConfig(total_steps=steps, checkpoint_dir=ckpt_dir,
                     learning_rate=1e-3 if smoke else 3e-4)
    mesh = make_host_mesh(model_parallel)
    rules = rules_for_mesh(mesh)
    dp = int(np.prod([mesh.shape[a] for a in rules.batch]))
    cfg = steps_lib.adapt_config(cfg, shape, dp)
    mb = steps_lib.microbatch_for(cfg, shape)
    accum = shape.global_batch // mb

    data = SyntheticLM(LMDataConfig(seq, batch, cfg.vocab_size))
    with mesh, active_mesh(mesh, rules):
        step_fn, optimizer = steps_lib.make_train_step(cfg, tc, rules)
        params = tfm.init(jax.random.PRNGKey(tc.seed), cfg)
        opt_state = optimizer.init(params)
        jstep = jax.jit(step_fn, donate_argnums=(0, 1))

        mgr = CheckpointManager(ckpt_dir, every=tc.checkpoint_every)
        start = 0
        if resume and mgr.latest_step() is not None:
            start = mgr.latest_step()
            state = mgr.restore({"params": params, "opt": opt_state,
                                 "step": jnp.zeros((), jnp.int32)})
            params, opt_state = state["params"], state["opt"]
            print(f"resumed from step {start}")

        pre = PreemptionHandler()
        anom = AnomalyDetector()
        dog = StepWatchdog()
        losses = []
        for step in range(start, steps):
            dog.start()
            b = make_batch(cfg, shape, data, step, accum, mb)
            params, opt_state, metrics = jstep(params, opt_state, b)
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            dt = dog.stop()
            losses.append(loss)
            if not anom.check(loss, gn):
                print(f"step {step}: ANOMALY skipped (loss={loss}, gn={gn})")
            if step % log_every == 0 or step == steps - 1:
                print(f"step {step:5d} loss {loss:.4f} gnorm {gn:.3f} "
                      f"{dt*1000:.0f}ms")
            mgr.maybe_save(step + 1, {"params": params, "opt": opt_state,
                                      "step": jnp.int32(step + 1)})
            if pre.preempted:
                print("preemption requested -> checkpoint + exit")
                mgr.maybe_save(step + 1,
                               {"params": params, "opt": opt_state,
                                "step": jnp.int32(step + 1)}, force=True)
                break
        mgr.wait()
    return losses


def train_hat(pretrain_steps: int = 40, meta_steps: int = 40,
              n_way: int = 6, k_shot: int = 3, n_query: int = 4,
              eval_episodes: int = 3, ckpt_dir: str = "/tmp/repro_hat_ckpt",
              seed: int = 0, log_every: int = 10) -> dict:
    """Two-stage hardware-aware training + the closed train->write->serve
    loop (see module docstring). Returns a metrics dict with the loss
    curves, the in-training/served eval accuracies, and whether every
    served prediction matched the in-training forward bit-for-bit."""
    from repro.configs.omniglot_conv4 import get_smoke_config
    from repro.core.avss import SearchConfig, class_mean_votes
    from repro.core.hat import HATConfig
    from repro.core.mcam import MCAMConfig
    from repro.data.fsl import EpisodeSampler, OmniglotLike, pretrain_batch
    from repro.engine import (MemoryStore, RetrievalEngine, SearchRequest)
    from repro.models.controller import apply_conv4, init_conv4
    from repro.optim import adamw

    fsl = get_smoke_config()
    ds = OmniglotLike(n_classes=fsl.n_train_classes + fsl.n_test_classes,
                      image_size=fsl.image_size, seed=0)
    train_ids = np.arange(fsl.n_train_classes)
    test_ids = np.arange(fsl.n_train_classes,
                         fsl.n_train_classes + fsl.n_test_classes)
    mesh = make_host_mesh(1)                       # DP over all local devices
    hat_cfg = HATConfig(search=SearchConfig(
        "mtmc", cl=fsl.cl, mode="avss", use_kernel="ref",
        mcam=MCAMConfig(sigma_device=0.15, sigma_read=0.05)))

    k_backbone, k_head = jax.random.split(jax.random.PRNGKey(seed))
    backbone = init_conv4(k_backbone, in_ch=1, width=32,
                          embed_dim=fsl.embed_dim)
    head = {"w": jax.random.normal(k_head,
                                   (fsl.embed_dim, len(train_ids))) * 0.05,
            "b": jnp.zeros((len(train_ids),))}
    pre_opt = adamw(1e-3, weight_decay=1e-4)
    meta_opt = adamw(1e-4, weight_decay=1e-4)  # gentle: adapt, don't destroy
    pre_step, meta_step, place = steps_lib.make_hat_train_steps(
        apply_conv4, hat_cfg, pre_opt, meta_opt, n_way=n_way, mesh=mesh)

    pre_losses, meta_losses = [], []
    with mesh:
        # stage 1: transferable features (plain CE, full training label set)
        params = {"backbone": backbone, "head": head}
        opt_state = pre_opt.init(params)
        t0 = time.time()
        for step in range(pretrain_steps):
            batch = place(pretrain_batch(ds, train_ids, batch=32, step=step))
            params, opt_state, loss = pre_step(params, opt_state, batch)
            pre_losses.append(float(loss))
            if step % log_every == 0 or step == pretrain_steps - 1:
                print(f"[hat/pretrain] step {step:4d} loss {float(loss):.4f} "
                      f"({time.time()-t0:.0f}s)")

        # stage 2: episodic meta-training THROUGH the simulated MCAM
        # (episode composition and the per-step hardware-noise streams all
        # derive from `seed`, so different seeds are independent replicates)
        sampler = EpisodeSampler(ds, train_ids, n_way=n_way, k_shot=k_shot,
                                 n_query=n_query, seed=11 + seed)
        meta_params = {"backbone": params["backbone"]}
        opt_state2 = meta_opt.init(meta_params)
        for step in range(meta_steps):
            ep = sampler.episode(step)
            arrays = place({"support_images": ep.support_images,
                            "support_labels": ep.support_labels,
                            "query_images": ep.query_images,
                            "query_labels": ep.query_labels})
            meta_params, opt_state2, loss = meta_step(
                meta_params, opt_state2, arrays,
                jax.random.fold_in(jax.random.PRNGKey(seed), step))
            meta_losses.append(float(loss))
            if step % log_every == 0 or step == meta_steps - 1:
                print(f"[hat/meta]     step {step:4d} loss {float(loss):.4f} "
                      f"({time.time()-t0:.0f}s)")

    # -- close the loop: trained controller -> calibrate/write -> search ----
    eng = RetrievalEngine(hat_cfg.search)
    eval_way = min(n_way, len(test_ids))
    eval_sampler = EpisodeSampler(ds, test_ids, n_way=eval_way,
                                  k_shot=k_shot, n_query=n_query,
                                  seed=77 + seed)
    train_acc, served_acc, parity = [], [], True
    store = None
    for e in range(eval_episodes):
        ep = eval_sampler.episode(e)
        s_emb = apply_conv4(meta_params["backbone"],
                            jnp.asarray(ep.support_images))
        q_emb = apply_conv4(meta_params["backbone"],
                            jnp.asarray(ep.query_images))
        s_lab = jnp.asarray(ep.support_labels)
        # the in-training evaluation head (noiseless episodic forward)
        scores = eng.episode_scores(q_emb, s_emb, s_lab, eval_way,
                                    clip_std=hat_cfg.clip_std,
                                    sa_tau=hat_cfg.sa_tau, noisy=False)
        pred_train = jnp.argmax(scores, -1)
        # the SERVED head: the shared train->write->serve recipe --
        # bit-identical to the in-training forward by construction
        store = MemoryStore.from_episode(s_emb, q_emb, s_lab,
                                         hat_cfg.search,
                                         clip_std=hat_cfg.clip_std)
        res = eng.search(store, q_emb,
                         SearchRequest(mode="full", noisy=False))
        served = class_mean_votes(res.votes, store.labels, eval_way)
        pred_served = jnp.argmax(served, -1)
        parity &= bool(jnp.array_equal(scores, served))
        q_lab = jnp.asarray(ep.query_labels)
        train_acc.append(float((pred_train == q_lab).mean()))
        served_acc.append(float((pred_served == q_lab).mean()))

    print(f"[hat/eval] in-training acc {np.mean(train_acc):.3f}  "
          f"served acc {np.mean(served_acc):.3f}  "
          f"score bit-parity: {parity}")

    # checkpoint controller + the last programmed store for separate serving
    mgr = CheckpointManager(ckpt_dir, every=1)
    mgr.maybe_save(meta_steps, {"params": meta_params}, force=True)
    mgr.wait()
    if store is not None:
        store.save(f"{ckpt_dir}/store", step=meta_steps)
    return {"pre_losses": pre_losses, "meta_losses": meta_losses,
            "train_acc": float(np.mean(train_acc)),
            "served_acc": float(np.mean(served_acc)),
            "parity": parity, "ckpt_dir": ckpt_dir}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--hat", action="store_true",
                    help="two-stage hardware-aware training (paper Sec. "
                         "3.3) + the closed train->write->serve loop")
    ap.add_argument("--hat-pretrain-steps", type=int, default=40)
    ap.add_argument("--hat-meta-steps", type=int, default=40)
    ap.add_argument("--hat-n-way", type=int, default=6)
    ap.add_argument("--hat-k-shot", type=int, default=3)
    ap.add_argument("--hat-eval-episodes", type=int, default=3)
    args = ap.parse_args(argv)
    if args.hat:
        out = train_hat(args.hat_pretrain_steps, args.hat_meta_steps,
                        args.hat_n_way, args.hat_k_shot,
                        eval_episodes=args.hat_eval_episodes,
                        ckpt_dir=args.ckpt_dir)
        print(f"HAT done: served acc {out['served_acc']:.3f} "
              f"(parity={out['parity']}); checkpoints in {out['ckpt_dir']}")
        return
    losses = train(args.arch, args.smoke, args.steps, args.batch, args.seq,
                   args.ckpt_dir, args.resume, args.model_parallel)
    print(f"first-10 mean {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean {np.mean(losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
