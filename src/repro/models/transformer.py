"""Model assembly: scan-over-layers transformer covering every assigned
architecture family (dense GQA, MoE, MLA, SWA hybrids, xLSTM, Mamba-parallel,
embedding-stub frontends).

Layers are partitioned into GROUPS of consecutive structurally-identical
layers (see ModelConfig.layer_groups); each group's parameters are stacked on
a leading axis and executed with lax.scan (+ optional jax.checkpoint remat),
keeping the HLO compact enough that a 126-layer 405B model compiles in
seconds on the multi-pod mesh.

Public API (all pure functions):
  init(key, cfg)                         -> params
  forward(params, cfg, batch, rules)     -> (logits, aux)        train/prefill
  loss_fn(params, cfg, batch, rules)     -> (loss, metrics)
  init_cache(cfg, batch, max_seq)        -> cache
  decode_step(params, cfg, tok, cache, pos, rules) -> (logits, cache)
  abstract_params(cfg) / shardings(cfg, mesh, rules)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.sharding import Rules, constrain

# --------------------------------------------------------------------------
# Init.
# --------------------------------------------------------------------------


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _init_layer(key, cfg: ModelConfig, layer_type: str, is_moe: bool):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": L.norm_init(cfg)}
    if layer_type in ("attn", "swa"):
        p["attn"] = L.attn_init(ks[0], cfg, dt)
    elif layer_type == "mla":
        p["attn"] = L.mla_init(ks[0], cfg, dt)
    elif layer_type in ("hymba", "hymba_g"):
        p["attn"] = L.attn_init(ks[0], cfg, dt)
        p["mamba"] = ssm_lib.mamba_init(ks[3], cfg, dt)
    elif layer_type == "mlstm":
        p["cell"] = ssm_lib.mlstm_init(ks[0], cfg, dt)
    elif layer_type == "slstm":
        p["cell"] = ssm_lib.slstm_init(ks[0], cfg, dt)
    else:
        raise ValueError(layer_type)
    has_ffn = cfg.d_ff > 0 or is_moe
    if has_ffn and not cfg.parallel_block:
        p["norm2"] = L.norm_init(cfg)
    if is_moe:
        p["moe"] = moe_lib.moe_init(ks[1], cfg, dt)
    elif cfg.d_ff > 0:
        p["mlp"] = L.mlp_init(ks[1], cfg, dt)
    return p


def _dense_ffn_width(cfg: ModelConfig, is_moe: bool) -> int:
    if not is_moe and cfg.moe is not None and cfg.moe.dense_d_ff:
        return cfg.moe.dense_d_ff
    return cfg.d_ff


def init(key, cfg: ModelConfig):
    keys = jax.random.split(key, len(cfg.layer_groups()) + 2)
    dt = _dtype(cfg)
    params: dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        params["embed"] = (jax.random.normal(keys[0],
                                             (cfg.vocab_size, cfg.d_model))
                           * 0.02).astype(dt)
    groups = []
    for gi, (ltype, is_moe, count) in enumerate(cfg.layer_groups()):
        gcfg = _group_cfg(cfg, is_moe)
        gkeys = jax.random.split(keys[gi + 1], count)
        groups.append(jax.vmap(
            lambda k: _init_layer(k, gcfg, ltype, is_moe))(gkeys))
    params["groups"] = groups
    params["final_norm"] = L.norm_init(cfg)
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(keys[-1],
                                         (cfg.d_model, cfg.vocab_size), dt)
    return params


def _group_cfg(cfg: ModelConfig, is_moe: bool) -> ModelConfig:
    """Dense layers inside MoE models may use a wider dense FFN."""
    w = _dense_ffn_width(cfg, is_moe)
    if w != cfg.d_ff:
        import dataclasses
        return dataclasses.replace(cfg, d_ff=w)
    return cfg


# --------------------------------------------------------------------------
# Layer application (shared by train/prefill and decode).
# --------------------------------------------------------------------------


import os as _os

# Perf iteration 2 (REPRO_OPT>=2, EXPERIMENTS.md §Perf): an optimization
# barrier on each block output pins the residual-stream tensor to bf16 at
# the point where the SPMD partitioner inserts the tensor-parallel psum --
# without it XLA hoists the f32 upcast (feeding the next norm) above the
# all-reduce, doubling its bytes.
_OPT_LEVEL = int(_os.environ.get("REPRO_OPT", "0") or 0)


def _barrier(y):
    return jax.lax.optimization_barrier(y) if _OPT_LEVEL >= 2 else y


def _layer_apply(p, x, cfg: ModelConfig, ltype: str, is_moe: bool,
                 rules: Rules, *, cache=None, pos0=0, positions3=None,
                 decode: bool = False):
    """Returns (x, new_cache, aux)."""
    aux = {"load_balance": jnp.zeros((), jnp.float32),
           "z_loss": jnp.zeros((), jnp.float32)}
    h = L.apply_norm(p["norm1"], x, cfg)
    new_cache = cache
    if ltype in ("attn", "swa", "hymba", "hymba_g"):
        window = cfg.window if ltype in ("swa", "hymba") else 0
        is_hymba = ltype.startswith("hymba")
        acache = (cache["attn"] if (is_hymba and cache is not None)
                  else cache)
        y, acache = L.attn_apply(p["attn"], h, cfg, layer_window=window,
                                 cache=acache, pos0=pos0,
                                 positions3=positions3)
        if is_hymba:
            if decode:
                ym, scache = ssm_lib.mamba_apply_step(
                    p["mamba"], h, cfg, cache["ssm"])
            else:
                ym, scache = ssm_lib.mamba_apply_seq(
                    p["mamba"], h, cfg,
                    None if cache is None else cache["ssm"])
            y = 0.5 * (y + ym)
            new_cache = {"attn": acache, "ssm": scache}
        elif not is_hymba:
            new_cache = acache
    elif ltype == "mla":
        y, new_cache = L.mla_apply(p["attn"], h, cfg, cache=cache, pos0=pos0)
    elif ltype == "mlstm":
        if decode:
            y, new_cache = ssm_lib.mlstm_apply_step(p["cell"], h, cfg, cache)
        else:
            y, new_cache = ssm_lib.mlstm_apply_seq(p["cell"], h, cfg, cache)
    elif ltype == "slstm":
        if decode:
            y, new_cache = ssm_lib.slstm_apply_step(p["cell"], h, cfg, cache)
        else:
            y, new_cache = ssm_lib.slstm_apply_seq(p["cell"], h, cfg, cache)
    else:
        raise ValueError(ltype)

    if cfg.parallel_block:
        # command-r style: x + attn(norm(x)) + mlp(norm(x)), single norm
        f = _barrier(_ffn(p, h, cfg, is_moe, rules, aux))
        x = x + _barrier(y) + f
        return _decode_stream(x, rules, decode), new_cache, aux
    x = x + _barrier(y)
    if ("norm2" in p) and (is_moe or cfg.d_ff > 0):
        h2 = L.apply_norm(p["norm2"], x, cfg)
        x = x + _barrier(_ffn(p, h2, cfg, is_moe, rules, aux))
    return _decode_stream(x, rules, decode), new_cache, aux


def _decode_stream(x, rules, decode):
    """Perf iteration 5 (REPRO_OPT>=5): hidden-dim-sharded decode residual.

    With decode activations replicated over DP (iteration 3), the w2/wo
    output projections still conflict with the weights' FSDP rows and the
    partitioner gathers ~208 MB of weights per layer per token. Sharding the
    tiny (B, 1, D) residual stream on D over the FSDP axes instead makes
    every projection a local partial dot + a KB-scale activation all-reduce:
    weights never move."""
    if decode and _OPT_LEVEL >= 5:
        from repro.models.sharding import aconstrain
        x = aconstrain(x, "batch", None, "fsdp")
    return x


def _ffn(p, h, cfg: ModelConfig, is_moe: bool, rules: Rules, aux: dict):
    if is_moe:
        y, a = moe_lib.moe_apply(p["moe"], h, cfg, rules)
        aux["load_balance"] += a["load_balance"]
        aux["z_loss"] += a["z_loss"]
        return y
    if cfg.d_ff > 0:
        return L.mlp_apply(p["mlp"], h, cfg)
    return jnp.zeros_like(h)


def _scan_group(params_g, x, cfg, ltype, is_moe, rules, *, caches=None,
                pos0=0, positions3=None, decode=False,
                collect_cache=False):
    """Run `count` stacked layers with lax.scan. caches: stacked or the
    empty sentinel; new caches are collected only when requested (so train
    steps never materialise stacked KV tensors)."""
    gcfg = _group_cfg(cfg, is_moe)
    collect = collect_cache or decode

    def body(carry, xs):
        x, lb, zl = carry
        p, c = xs
        if _is_empty(c):
            c = None
        x, new_c, aux = _layer_apply(p, x, gcfg, ltype, is_moe, rules,
                                     cache=c, pos0=pos0,
                                     positions3=positions3, decode=decode)
        y = new_c if collect else jnp.zeros((0,))
        return (x, lb + aux["load_balance"], zl + aux["z_loss"]), y

    if cfg.remat and not decode:
        body = jax.checkpoint(body, prevent_cse=False)
    n = jax.tree_util.tree_leaves(params_g)[0].shape[0]
    if caches is None:
        caches = _none_tree(n)
    carry = (x, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    if cfg.scan_layers:
        (x, lb, zl), new_caches = jax.lax.scan(body, carry,
                                               (params_g, caches))
        return x, new_caches, lb, zl
    # unrolled python loop (dry-run cost calibration: every layer body
    # appears in the HLO so cost_analysis counts all of them)
    ys = []
    for i in range(n):
        sl = jax.tree_util.tree_map(lambda a: a[i], (params_g, caches))
        carry, y = body(carry, sl)
        ys.append(y)
    x, lb, zl = carry
    new_caches = jax.tree_util.tree_map(
        lambda *a: jnp.stack(a, 0), *ys) if ys else None
    return x, new_caches, lb, zl


def _none_tree(n):
    return jnp.zeros((n, 0))  # dummy scanned input when no cache exists


def _is_empty(c):
    return hasattr(c, "size") and getattr(c, "size", 1) == 0


# --------------------------------------------------------------------------
# Forward passes.
# --------------------------------------------------------------------------


def _embed_in(params, cfg: ModelConfig, batch, rules: Rules, pos0=0):
    if cfg.input_mode == "tokens":
        x = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.dtype))
    else:
        x = batch["embeddings"].astype(jnp.dtype(cfg.dtype))
    if cfg.pos_embed == "sinusoidal":
        S, D = x.shape[1], x.shape[2]
        pos = pos0 + jnp.arange(S)
        sin, cos = L.rope_sincos(pos, D, 10000.0)
        x = x + jnp.concatenate([sin, cos], -1)[None].astype(x.dtype)
    return constrain(x, rules, "batch", None, None)


def _logits_out(params, cfg: ModelConfig, x, rules: Rules):
    """Vocab-sharded logits, kept in the compute dtype: consumers must not
    gather the full vocab axis (loss_fn uses vocab-parallel CE)."""
    x = L.apply_norm(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["unembed"]
    if cfg.logit_softcap:
        logits = (jnp.tanh(logits.astype(jnp.float32) / cfg.logit_softcap)
                  * cfg.logit_softcap).astype(logits.dtype)
    return constrain(logits, rules, "batch", None, "tensor")


def forward(params, cfg: ModelConfig, batch, rules: Rules | None = None,
            return_cache: bool = False, last_only: bool = False):
    """Train/prefill forward. batch: tokens (B,S) | embeddings (B,S,D)
    [+ positions3 (B,S,3) for mrope]. Returns (logits, aux[, cache]).
    last_only: compute logits for the final position only (prefill serving;
    avoids the (B,S,V) fp32 tensor)."""
    rules = rules or Rules(batch=(), fsdp=(), tensor=(), expert=())
    x = _embed_in(params, cfg, batch, rules)
    positions3 = batch.get("positions3")
    lb = zl = jnp.zeros((), jnp.float32)
    caches = []
    for params_g, (ltype, is_moe, count) in zip(params["groups"],
                                                cfg.layer_groups()):
        x, new_c, l, z = _scan_group(params_g, x, cfg, ltype, is_moe, rules,
                                     positions3=positions3,
                                     collect_cache=return_cache)
        if return_cache:
            caches.append(new_c)
        lb, zl = lb + l, zl + z
    if last_only:
        x = x[:, -1:]
    logits = _logits_out(params, cfg, x, rules)
    aux = {"load_balance": lb, "z_loss": zl}
    if return_cache:
        return logits, aux, caches
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch, rules: Rules | None = None):
    """Vocab-PARALLEL cross entropy: per-shard logsumexp + one-hot label
    contraction, so only (B, S)-sized statistics cross the tensor axis (the
    full fp32 (B, S, V) log-softmax would otherwise be all-gathered)."""
    logits, aux = forward(params, cfg, batch, rules)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)                      # (B, S)
    onehot = jax.nn.one_hot(labels, cfg.vocab_size, dtype=logits.dtype)
    label_logit = jnp.einsum("bsv,bsv->bs", lf,
                             onehot.astype(jnp.float32))
    nll = lse - label_logit
    mask = (labels >= 0).astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_weight * aux["load_balance"] \
                    + 1e-3 * aux["z_loss"]
    return loss, {"nll": loss, "load_balance": aux["load_balance"]}


# --------------------------------------------------------------------------
# Decode.
# --------------------------------------------------------------------------


def _cache_for_layer(cfg: ModelConfig, ltype: str, batch: int, max_seq: int,
                     prefill: bool = False):
    dt = jnp.dtype(cfg.dtype)
    if ltype == "attn":
        return L.attn_cache_init(cfg, batch, max_seq, 0, dt)
    if ltype == "swa":
        return L.attn_cache_init(cfg, batch, max_seq, cfg.window, dt)
    if ltype == "mla":
        return L.mla_cache_init(cfg, batch, max_seq, dt)
    if ltype in ("hymba", "hymba_g"):
        w = cfg.window if ltype == "hymba" else 0
        return {"attn": L.attn_cache_init(cfg, batch, max_seq, w, dt),
                "ssm": ssm_lib.mamba_state_init(cfg, batch)}
    if ltype == "mlstm":
        return ssm_lib.mlstm_state_init(cfg, batch)
    if ltype == "slstm":
        return ssm_lib.slstm_state_init(cfg, batch)
    return None


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    caches = []
    for (ltype, is_moe, count) in cfg.layer_groups():
        one = _cache_for_layer(cfg, ltype, batch, max_seq)
        if one is None:
            caches.append(_none_tree(count))
        else:
            caches.append(jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (count,) + a.shape).copy(), one))
    return caches


def decode_step(params, cfg: ModelConfig, batch, caches, pos,
                rules: Rules | None = None, return_hidden: bool = False):
    """One token for every sequence. batch: tokens (B,1) | embeddings
    (B,1,D) [+ positions3 (B,1,3)]; pos: scalar int32 current position.
    Returns (logits (B,1,V), new_caches[, hidden (B,1,D)])."""
    rules = rules or Rules(batch=(), fsdp=(), tensor=(), expert=())
    x = _embed_in(params, cfg, batch, rules, pos0=pos)
    x = _decode_stream(x, rules, True)
    positions3 = batch.get("positions3")
    new_caches = []
    for params_g, caches_g, (ltype, is_moe, count) in zip(
            params["groups"], caches, cfg.layer_groups()):
        x, nc, _, _ = _scan_group(params_g, x, cfg, ltype, is_moe, rules,
                                  caches=caches_g, pos0=pos,
                                  positions3=positions3, decode=True)
        new_caches.append(nc)
    logits = _logits_out(params, cfg, x, rules)
    if return_hidden:
        return logits, new_caches, x
    return logits, new_caches


# --------------------------------------------------------------------------
# Abstract params + shardings (dry-run path: no allocation).
# --------------------------------------------------------------------------


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init(k, cfg), jax.random.PRNGKey(0))


from repro.models.sharding import legalize_spec as _legalize  # noqa: E402


def shardings(cfg: ModelConfig, mesh, rules: Rules):
    """NamedSharding tree for params (legalized against actual dims)."""
    aps = abstract_params(cfg)
    specs = L.param_specs(aps)

    def mk(leaf, spec):
        pspec = rules.resolve(*spec.logical)
        pspec = _legalize(pspec, leaf.shape, mesh)
        return NamedSharding(mesh, pspec)

    return jax.tree_util.tree_map(
        mk, aps, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
