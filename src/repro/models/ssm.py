"""Recurrent blocks: xLSTM's mLSTM/sLSTM cells and Mamba selective SSM.

* mLSTM -- matrix-memory LSTM with exponential gating. Implemented in
  CHUNKWISE-PARALLEL form (linear-attention-style within chunks, recurrence
  across chunks) so the MXU sees dense einsums instead of a length-S scan;
  a per-step reference is kept for tests. O(1) decode state:
  (C (H, dh, dh), n (H, dh), m (H)).
* sLSTM -- scalar-memory LSTM with exponential gating and recurrent weights;
  inherently sequential, lax.scan over time.
* Mamba -- S6 selective SSM via associative scan (parallel prefill/train,
  O(1) decode: (ssm_state, conv ring)).

All three expose   init / apply_seq(x) -> (y, state) / apply_step(x1, state).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.sharding import aconstrain

# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


def mlstm_init(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    di = 2 * D                        # pre-up-projection inner width
    H = cfg.n_heads
    dh = di // H
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], (D, di), dtype),
        "wq": dense_init(ks[1], (di, H, dh), dtype),
        "wk": dense_init(ks[2], (di, H, dh), dtype),
        "wv": dense_init(ks[3], (di, H, dh), dtype),
        "w_gates": dense_init(ks[4], (D, 2 * H), dtype),   # (i, f) pre-acts
        "gate_bias": jnp.concatenate(
            [jnp.zeros((H,)), 3.0 + jnp.arange(H, dtype=jnp.float32) * 0.5]
        ).astype(jnp.float32),                             # forget bias high
        "w_ogate": dense_init(ks[5], (D, H, dh), dtype),
        "out_proj": dense_init(ks[6], (di, D), dtype),
    }


def _mlstm_qkv(p, x, cfg):
    B, S, D = x.shape
    H = cfg.n_heads
    xin = aconstrain(x @ p["in_proj"], "batch", None, "tensor")
    q = jnp.einsum("bsd,dhk->bshk", xin, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xin, p["wk"]) / math.sqrt(q.shape[-1])
    v = jnp.einsum("bsd,dhk->bshk", xin, p["wv"])
    gates = (x @ p["w_gates"]).astype(jnp.float32) + p["gate_bias"]
    li = gates[..., :H]                                   # log input gate
    lf = jax.nn.log_sigmoid(gates[..., H:])               # log forget gate
    o = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bshk", x, p["w_ogate"]))
    return q, k, v, li, lf, o


def mlstm_state_init(cfg: ModelConfig, batch, dtype=jnp.float32):
    H = cfg.n_heads
    dh = 2 * cfg.d_model // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), dtype),
        "n": jnp.zeros((batch, H, dh), dtype),
        "m": jnp.full((batch, H), -1e30, dtype),
    }


def mlstm_apply_seq(p, x, cfg: ModelConfig, state=None, chunk=64):
    """Chunkwise-parallel mLSTM. x (B, S, D) -> (y (B, S, D), state)."""
    B, S, D = x.shape
    H = cfg.n_heads
    q, k, v, li, lf, o = _mlstm_qkv(p, x, cfg)
    dh = q.shape[-1]
    if state is None:
        state = mlstm_state_init(cfg, B)
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L
    rs = lambda t: jnp.moveaxis(t.reshape(B, nc, L, *t.shape[2:]), 1, 0)
    qs, ks_, vs, lis, lfs, = map(rs, (q, k, v, li, lf))

    def chunk_body(carry, xs):
        C, n, m_in = carry                                # (B,H,dh,dh) ...
        qc, kc, vc, lic, lfc = xs                         # (B,L,H,*)
        lic = jnp.moveaxis(lic, 1, 2)                     # (B,H,L)
        lfc = jnp.moveaxis(lfc, 1, 2)
        b = jnp.cumsum(lfc, axis=-1)                      # (B,H,L) decay-from-start
        a = lic - b                                       # log(i_j / decay_j)
        g = jnp.maximum(m_in[..., None], jax.lax.cummax(a, axis=a.ndim - 1))
        # intra-chunk weights w[t, j] = exp(a_j - g_t) for j <= t
        w = jnp.exp(a[..., None, :] - g[..., :, None])    # (B,H,L,L)
        causal = jnp.tril(jnp.ones((L, L), bool))
        w = jnp.where(causal, w, 0.0)
        qkt = jnp.einsum("blhk,bjhk->bhlj", qc, kc).astype(jnp.float32)
        sc = qkt * w                                      # (B,H,L,L)
        inter = jnp.exp(m_in[..., None] - g)              # (B,H,L)
        num = (jnp.einsum("bhlj,bjhk->blhk", sc.astype(vc.dtype), vc)
               + jnp.einsum("blhk,bhkv,bhl->blhv", qc.astype(jnp.float32),
                            C, inter).astype(vc.dtype))
        # normalizer n_t^T q_t = sum_j w_tj (k_j . q_t)  [already in sc]
        nq = (sc.sum(-1)
              + jnp.einsum("bhk,blhk,bhl->bhl", n,
                           qc.astype(jnp.float32), inter))
        m_t = b + g                                       # (B,H,L)
        den = jnp.maximum(jnp.abs(nq), jnp.exp(-m_t)) + 1e-6
        h = num / jnp.moveaxis(den, 1, 2)[..., None].astype(num.dtype)
        # chunk-end state
        g_out = g[..., -1]
        wout = jnp.exp(a - g_out[..., None])              # (B,H,L)
        C_new = (C * jnp.exp(m_in - g_out)[..., None, None]
                 + jnp.einsum("bhl,blhk,blhv->bhkv", wout,
                              kc.astype(jnp.float32), vc.astype(jnp.float32)))
        n_new = (n * jnp.exp(m_in - g_out)[..., None]
                 + jnp.einsum("bhl,blhk->bhk", wout, kc.astype(jnp.float32)))
        m_new = b[..., -1] + g_out
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(
        chunk_body, (state["C"], state["n"], state["m"]),
        (qs, ks_, vs, lis, lfs))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, dh)
    y = (o * h).reshape(B, S, -1) @ p["out_proj"]
    return y, {"C": C, "n": n, "m": m}


def mlstm_apply_step(p, x1, cfg: ModelConfig, state):
    """x1 (B, 1, D) single decode step (exact per-step recurrence)."""
    q, k, v, li, lf, o = _mlstm_qkv(p, x1, cfg)
    q, k, v, o = (t[:, 0].astype(jnp.float32) for t in (q, k, v, o))
    li, lf = li[:, 0], lf[:, 0]                           # (B,H)
    C, n, m_in = state["C"], state["n"], state["m"]
    m_t = jnp.maximum(lf + m_in, li)
    fp = jnp.exp(lf + m_in - m_t)
    ip = jnp.exp(li - m_t)
    C = C * fp[..., None, None] + ip[..., None, None] * (
        k[..., :, None] * v[..., None, :])                # (B,H,dh,dh)
    n = n * fp[..., None] + ip[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs((n * q).sum(-1)), jnp.exp(-m_t)) + 1e-6
    h = (o * (num / den[..., None]))[:, None]             # (B,1,H,dh)
    y = h.reshape(*x1.shape[:2], -1).astype(x1.dtype) @ p["out_proj"]
    return y, {"C": C, "n": n, "m": m_t}


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------


def slstm_init(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    H = cfg.n_heads
    dh = D // H
    ks = jax.random.split(key, 8)
    p = {}
    for i, g in enumerate("zifo"):
        p[f"w_{g}"] = dense_init(ks[i], (D, H, dh), dtype)
        p[f"r_{g}"] = dense_init(ks[4 + i], (H, dh, dh), dtype)
        p[f"b_{g}"] = (jnp.full((H, dh), 3.0, jnp.float32) if g == "f"
                       else jnp.zeros((H, dh), jnp.float32))
    return p


def slstm_state_init(cfg: ModelConfig, batch, dtype=jnp.float32):
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), dtype)
    return {"c": z, "n": z + 1e-6, "h": z, "m": jnp.full((batch, H, dh), -1e30, dtype)}


def _slstm_cell(p, xw, state):
    """xw: dict g -> (B, H, dh) pre-activations from the input path."""
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    pre = {g: (xw[g]
               + jnp.einsum("bhk,hkj->bhj", h, p[f"r_{g}"].astype(jnp.float32))
               + p[f"b_{g}"]) for g in "zifo"}
    z = jnp.tanh(pre["z"])
    o = jax.nn.sigmoid(pre["o"])
    li, lf = pre["i"], jax.nn.log_sigmoid(pre["f"])
    m_t = jnp.maximum(lf + m, li)
    ip = jnp.exp(li - m_t)
    fp = jnp.exp(lf + m - m_t)
    c = fp * c + ip * z
    n = fp * n + ip
    h = o * c / (jnp.abs(n) + 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_t}


def slstm_apply_seq(p, x, cfg: ModelConfig, state=None):
    B, S, D = x.shape
    H = cfg.n_heads
    if state is None:
        state = slstm_state_init(cfg, B)
    xw = {g: jnp.einsum("bsd,dhk->bshk", x, p[f"w_{g}"]).astype(jnp.float32)
          for g in "zifo"}

    def body(st, xs):
        st = _slstm_cell(p, xs, st)
        return st, st["h"]

    state, hs = jax.lax.scan(
        body, state, {g: jnp.moveaxis(xw[g], 1, 0) for g in "zifo"})
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, D).astype(x.dtype)
    return y, state


def slstm_apply_step(p, x1, cfg: ModelConfig, state):
    xw = {g: jnp.einsum("bsd,dhk->bshk", x1, p[f"w_{g}"])[:, 0].astype(jnp.float32)
          for g in "zifo"}
    state = _slstm_cell(p, xw, state)
    y = state["h"].reshape(x1.shape[0], 1, -1).astype(x1.dtype)
    return y, state


# --------------------------------------------------------------------------
# Mamba (S6)
# --------------------------------------------------------------------------


def _mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    di = int(s.expand * cfg.d_model)
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return di, dt_rank, s.d_state, s.d_conv


def mamba_init(key, cfg: ModelConfig, dtype):
    di, dt_rank, ds, dc = _mamba_dims(cfg)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (dc, di), dtype, scale_axis=dc),
        "x_proj": dense_init(ks[2], (di, dt_rank + 2 * ds), dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, di), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (di,),
                                       minval=math.log(1e-3),
                                       maxval=math.log(1e-1))))),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[0], (di, cfg.d_model), dtype),
    }


def mamba_state_init(cfg: ModelConfig, batch, dtype=jnp.float32):
    di, _, ds, dc = _mamba_dims(cfg)
    return {"h": jnp.zeros((batch, di, ds), dtype),
            "conv": jnp.zeros((batch, dc - 1, di), dtype)}


def _mamba_ssm_inputs(p, xz, cfg):
    di, dt_rank, ds, _ = _mamba_dims(cfg)
    x, z = xz[..., :di], xz[..., di:]
    dbc = x @ p["x_proj"]
    dt = jax.nn.softplus(dbc[..., :dt_rank] @ p["dt_proj"]
                         + p["dt_bias"]).astype(jnp.float32)   # (B,S,di)
    Bm = dbc[..., dt_rank:dt_rank + ds].astype(jnp.float32)    # (B,S,ds)
    Cm = dbc[..., dt_rank + ds:].astype(jnp.float32)
    A = -jnp.exp(p["a_log"])                                   # (di,ds)
    a_bar = jnp.exp(dt[..., None] * A)                         # (B,S,di,ds)
    b_x = (dt * x.astype(jnp.float32))[..., None] * Bm[..., None, :]
    return x, z, a_bar, b_x, Cm


def mamba_apply_seq(p, xin, cfg: ModelConfig, state=None):
    B, S, D = xin.shape
    di, _, ds, dc = _mamba_dims(cfg)
    if state is None:
        state = mamba_state_init(cfg, B)
    xz = aconstrain(xin @ p["in_proj"], "batch", None, "tensor")
    x_part = xz[..., :di]
    # depthwise causal conv over time, seeded with the conv ring state
    xpad = jnp.concatenate([state["conv"].astype(xz.dtype), x_part], axis=1)
    idx = jnp.arange(S)[:, None] + jnp.arange(dc)[None, :]     # (S, dc)
    windows = xpad[:, idx]                                     # (B,S,dc,di)
    xc = jax.nn.silu(jnp.einsum("bswd,wd->bsd", windows, p["conv_w"]))
    xz = jnp.concatenate([xc, xz[..., di:]], axis=-1)
    x, z, a_bar, b_x, Cm = _mamba_ssm_inputs(p, xz, cfg)
    # prepend carried state as step 0 with a=1
    a_all = jnp.concatenate(
        [jnp.ones((B, 1, di, ds), jnp.float32), a_bar], axis=1)
    b_all = jnp.concatenate([state["h"][:, None].astype(jnp.float32), b_x],
                            axis=1)

    def combine(lhs, rhs):
        (al, bl), (ar, br) = lhs, rhs
        return al * ar, bl * ar + br

    _, hs = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
    hs = hs[:, 1:]                                             # (B,S,di,ds)
    y = jnp.einsum("bsdn,bsn->bsd", hs, Cm)
    y = y + p["d_skip"] * x.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(xin.dtype)
    new_state = {"h": hs[:, -1], "conv": xpad[:, -(dc - 1):].astype(jnp.float32)}
    return y @ p["out_proj"], new_state


def mamba_apply_step(p, x1, cfg: ModelConfig, state):
    B = x1.shape[0]
    di, _, ds, dc = _mamba_dims(cfg)
    xz = x1 @ p["in_proj"]                                     # (B,1,2di)
    x_part = xz[..., :di]
    xpad = jnp.concatenate([state["conv"].astype(xz.dtype), x_part], axis=1)
    xc = jax.nn.silu(jnp.einsum("bwd,wd->bd", xpad, p["conv_w"]))[:, None]
    xz = jnp.concatenate([xc, xz[..., di:]], axis=-1)
    x, z, a_bar, b_x, Cm = _mamba_ssm_inputs(p, xz, cfg)
    h = state["h"].astype(jnp.float32) * a_bar[:, 0] + b_x[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None]
    y = y + p["d_skip"] * x.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x1.dtype)
    return y @ p["out_proj"], {"h": h, "conv": xpad[:, 1:].astype(jnp.float32)}
