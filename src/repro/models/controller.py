"""Feature-extraction controllers for the paper's MANN experiments.

Conv4  (Vinyals et al. [3])   -- Omniglot, 48-d embeddings (paper Sec. 4.1).
ResNet12 (Oreshkin et al. [33]) -- CUB, 480-d embeddings.

Pure functional JAX (init_* -> params pytree, apply_* -> embeddings). We use
GroupNorm instead of BatchNorm so train == eval behaviour (no running stats to
checkpoint); this does not affect any paper claim, which are all deltas
between encodings/search modes on the same controller.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout)) * math.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((cout,))}


def _conv(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _group_norm(x, groups=8, eps=1e-5):
    n, h, w, c = x.shape
    g = math.gcd(groups, c)
    xg = x.reshape(n, h, w, g, c // g)
    mu = xg.mean((1, 2, 4), keepdims=True)
    var = xg.var((1, 2, 4), keepdims=True)
    return ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(n, h, w, c)


def _maxpool(x, k=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")


# ---------------------------------------------------------------------------
# Conv4
# ---------------------------------------------------------------------------


def init_conv4(key, in_ch=1, width=64, embed_dim=48):
    keys = jax.random.split(key, 5)
    params = {"blocks": []}
    cin = in_ch
    for i in range(4):
        params["blocks"].append(_conv_init(keys[i], 3, 3, cin, width))
        cin = width
    params["proj"] = {
        "w": jax.random.normal(keys[4], (width, embed_dim)) / math.sqrt(width),
        "b": jnp.zeros((embed_dim,)),
    }
    return params


def apply_conv4(params, images):
    """images (B, H, W, C) -> (B, embed_dim) non-negative embeddings."""
    x = images
    for blk in params["blocks"]:
        x = _conv(blk, x)
        x = _group_norm(x)
        x = jax.nn.relu(x)
        if min(x.shape[1], x.shape[2]) >= 2:
            x = _maxpool(x)
    x = x.mean((1, 2))                                     # GAP
    x = x @ params["proj"]["w"] + params["proj"]["b"]
    return jax.nn.relu(x)  # non-negative, as MCAM stores unsigned levels


# ---------------------------------------------------------------------------
# ResNet12
# ---------------------------------------------------------------------------


def _res_block_init(key, cin, cout):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "c1": _conv_init(k1, 3, 3, cin, cout),
        "c2": _conv_init(k2, 3, 3, cout, cout),
        "c3": _conv_init(k3, 3, 3, cout, cout),
        "sc": _conv_init(k4, 1, 1, cin, cout),
    }


def _res_block(p, x):
    h = jax.nn.relu(_group_norm(_conv(p["c1"], x)))
    h = jax.nn.relu(_group_norm(_conv(p["c2"], h)))
    h = _group_norm(_conv(p["c3"], h))
    x = _group_norm(_conv(p["sc"], x))
    h = jax.nn.relu(h + x)
    if min(h.shape[1], h.shape[2]) >= 2:
        h = _maxpool(h)
    return h


def init_resnet12(key, in_ch=3, widths=(64, 160, 320, 640), embed_dim=480):
    keys = jax.random.split(key, len(widths) + 1)
    params = {"blocks": []}
    cin = in_ch
    for i, w in enumerate(widths):
        params["blocks"].append(_res_block_init(keys[i], cin, w))
        cin = w
    params["proj"] = {
        "w": jax.random.normal(keys[-1], (cin, embed_dim)) / math.sqrt(cin),
        "b": jnp.zeros((embed_dim,)),
    }
    return params


def apply_resnet12(params, images):
    x = images
    for blk in params["blocks"]:
        x = _res_block(blk, x)
    x = x.mean((1, 2))
    x = x @ params["proj"]["w"] + params["proj"]["b"]
    return jax.nn.relu(x)


CONTROLLERS = {
    "conv4": (init_conv4, apply_conv4),
    "resnet12": (init_resnet12, apply_resnet12),
}
