"""Mixture-of-Experts FFN (DeepSeek-style: shared + fine-grained routed).

Expert parallelism is expressed with capacity-based one-hot dispatch/combine
einsums whose GROUP axis maps onto the data-parallel mesh axis and whose
EXPERT axis maps onto the model axis, so the partitioner executes each
(group, expert-shard) block exactly once per device pair -- per-device
dispatch FLOPs are T_loc * E_loc * C * d (see DESIGN.md Sec. 7; the sort-based
dispatch that removes this overhead is a recorded perf iteration).

Aux losses: switch-style load balancing + router z-loss; both returned so the
train step can weight them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import dense_init
from repro.models.sharding import Rules, constrain


def moe_init(key, cfg: ModelConfig, dtype):
    m: MoEConfig = cfg.moe
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (cfg.d_model, m.n_routed), jnp.float32),
        "we1": dense_init(ks[1], (m.n_routed, cfg.d_model, m.d_ff), dtype),
        "we2": dense_init(ks[2], (m.n_routed, m.d_ff, cfg.d_model), dtype),
        "we3": dense_init(ks[3], (m.n_routed, cfg.d_model, m.d_ff), dtype),
    }
    if m.n_shared:
        sk = jax.random.split(ks[4], 3)
        dsh = m.n_shared * m.d_ff
        p["shared"] = {
            "w1": dense_init(sk[0], (cfg.d_model, dsh), dtype),
            "w2": dense_init(sk[1], (dsh, cfg.d_model), dtype),
            "w3": dense_init(sk[2], (cfg.d_model, dsh), dtype),
        }
    return p


def _capacity(tokens_per_group: int, m: MoEConfig) -> int:
    c = int(tokens_per_group * m.top_k / m.n_routed * m.capacity_factor) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8 for clean tiling


def moe_apply(p, x, cfg: ModelConfig, rules: Rules | None = None):
    """x (B, S, D) -> (y, aux) with aux = {load_balance, z_loss}."""
    m: MoEConfig = cfg.moe
    rules = rules or Rules(batch=(), fsdp=(), tensor=(), expert=())
    B, S, D = x.shape
    T = B * S
    G = min(m.groups, T)
    while T % G:
        G -= 1
    Sg = T // G
    xt = x.reshape(G, Sg, D)
    xt = constrain(xt, rules, "batch", None, None)

    logits = (xt.astype(jnp.float32) @ p["router"])            # (G,Sg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, m.top_k)                 # (G,Sg,k)
    gate = gate / (gate.sum(-1, keepdims=True) + 1e-9)         # renormalise

    E = m.n_routed
    C = _capacity(Sg, m)
    # position of each (token, k) within its expert queue
    sel = jax.nn.one_hot(eidx, E, dtype=jnp.int32)             # (G,Sg,k,E)
    flat_sel = sel.reshape(G, Sg * m.top_k, E)
    pos = jnp.cumsum(flat_sel, axis=1) - flat_sel              # (G,Sg*k,E)
    pos = pos.reshape(G, Sg, m.top_k, E)
    within = (pos < C) & (sel > 0)
    # dispatch mask (G,Sg,E,C) bf16 one-hot of queue slots
    slot_oh = jax.nn.one_hot(jnp.where(within, pos, C), C + 1,
                             dtype=x.dtype)[..., :C]           # (G,Sg,k,E,C)
    dispatch = (slot_oh * within[..., None].astype(x.dtype)).sum(2)
    dispatch = constrain(dispatch, rules, "batch", None, "expert", None)
    combine = (slot_oh * (gate[..., None, None]
                          * within[..., None].astype(jnp.float32)
                          ).astype(x.dtype)).sum(2)            # (G,Sg,E,C)
    combine = constrain(combine, rules, "batch", None, "expert", None)

    xe = jnp.einsum("gsd,gsec->gecd", xt, dispatch)
    xe = constrain(xe, rules, "batch", "expert", None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, p["we1"])
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xe, p["we3"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["we2"])
    ye = constrain(ye, rules, "batch", "expert", None, None)
    y = jnp.einsum("gecd,gsec->gsd", ye, combine)

    if m.n_shared:
        sh = p["shared"]
        hs = jax.nn.silu(xt @ sh["w1"]) * (xt @ sh["w3"])
        y = y + hs @ sh["w2"]

    # aux losses (switch-style: balanced routing => load_balance == 1.0)
    me = probs.mean((0, 1))                                    # (E,)
    ce = sel.sum(2).astype(jnp.float32).mean((0, 1)) / m.top_k
    load_balance = E * (me * ce).sum()
    z_loss = (jax.nn.logsumexp(logits, axis=-1) ** 2).mean()
    return y.reshape(B, S, D), {"load_balance": load_balance, "z_loss": z_loss}
