"""Logical-axis sharding rules (MaxText-style).

Model code annotates every parameter and activation with LOGICAL axis names;
a `Rules` table maps them onto physical mesh axes per deployment. The
production meshes are (16, 16) ("data", "model") and (2, 16, 16)
("pod", "data", "model"); the pod axis joins the data-parallel/FSDP dimension.

  batch   -- data-parallel batch sharding of activations
  fsdp    -- ZeRO-3-style weight/optimizer row sharding (gathered per layer)
  tensor  -- Megatron-style head/ffn/vocab column sharding
  expert  -- MoE routed-expert sharding
  seq     -- sequence parallelism (long-context KV caches)
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Rules:
    batch: tuple = ("data",)
    fsdp: tuple = ("data",)
    tensor: tuple = ("model",)
    expert: tuple = ("model",)
    seq: tuple = ()

    def resolve(self, *logical: str | None) -> P:
        """Logical axis names -> PartitionSpec."""
        out = []
        for name in logical:
            if name is None:
                out.append(None)
                continue
            axes = getattr(self, name)
            if not axes:
                out.append(None)
            elif len(axes) == 1:
                out.append(axes[0])
            else:
                out.append(tuple(axes))
        return P(*out)


def rules_for_mesh(mesh: Mesh, *, seq_sharding: bool = False) -> Rules:
    """Default rules for the production meshes."""
    names = mesh.axis_names
    dp = ("pod", "data") if "pod" in names else ("data",)
    return Rules(
        batch=dp,
        fsdp=dp,
        tensor=("model",),
        expert=("model",),
        seq=("data",) if seq_sharding else (),
    )


def logical_sharding(mesh: Mesh, rules: Rules, *logical) -> NamedSharding:
    return NamedSharding(mesh, rules.resolve(*logical))


_ACTIVE_MESH: list = [None]
_ACTIVE_RULES: list = [None]


class active_mesh:
    """Context manager giving `constrain`/`aconstrain` a mesh (and optional
    Rules) to bind PartitionSpecs to, so layer code can annotate activation
    shardings without threading mesh/rules through every call."""

    def __init__(self, mesh, rules=None):
        self.mesh = mesh
        self.rules = rules

    def __enter__(self):
        _ACTIVE_MESH[0] = self.mesh
        _ACTIVE_RULES[0] = self.rules
        return self.mesh

    def __exit__(self, *exc):
        _ACTIVE_MESH[0] = None
        _ACTIVE_RULES[0] = None
        return False


def aconstrain(x: jax.Array, *logical) -> jax.Array:
    """Activation sharding constraint using the ACTIVE mesh/rules; no-op
    when no context is installed (plain CPU tests) or when the resolved
    spec is all-None -- an explicit replicated pin would FORCE the
    partitioner to materialise the full tensor (e.g. gathering FSDP weights
    into a decode step, EXPERIMENTS.md §Perf iteration 5)."""
    mesh, rules = _ACTIVE_MESH[0], _ACTIVE_RULES[0]
    if mesh is None or rules is None:
        return x
    spec = legalize_spec(rules.resolve(*logical), x.shape, mesh)
    if all(p is None for p in spec):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except (ValueError, RuntimeError, TypeError):
        return x


def legalize_spec(spec: P, shape, mesh) -> P:
    """DROP mesh axes whose size doesn't divide the dim they shard.

    Deliberately no shifting to neighbouring dims: shifting `tensor` onto a
    contraction-participating dim (e.g. head_dim when n_heads % tp != 0)
    turns every attention score matrix into a partial-sum all-reduce --
    measured at 12 GB/layer on starcoder2 prefill (EXPERIMENTS.md Sec. Perf,
    iteration 0). Replicating the indivisible dim is strictly cheaper.
    """
    import numpy as np
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ax_size = lambda a: (int(np.prod([sizes[x] for x in a]))
                         if isinstance(a, tuple) else sizes[a])
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = [None] * len(shape)
    for i, p in enumerate(parts):
        if p is None:
            continue
        if shape[i] % ax_size(p) == 0:
            out[i] = p
    return P(*out)


def constrain(x: jax.Array, rules: Rules, *logical) -> jax.Array:
    """with_sharding_constraint by logical names (no-op outside jit/mesh,
    and for all-None specs -- see aconstrain)."""
    spec = rules.resolve(*logical)
    mesh = _ACTIVE_MESH[0]
    try:
        if mesh is not None:
            spec = legalize_spec(spec, x.shape, mesh)
            if all(p is None for p in spec):
                return x
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        if all(p is None for p in spec):
            return x
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, TypeError):
        return x


# Parameter logical specs, keyed by param-tree path leaf conventions. The
# model init functions attach these via `ParamSpec` alongside the arrays.

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Logical axes for one parameter array ('.' entries are unsharded)."""
    logical: tuple

    def sharding(self, mesh: Mesh, rules: Rules) -> NamedSharding:
        return logical_sharding(mesh, rules, *self.logical)


def tree_shardings(spec_tree, mesh: Mesh, rules: Rules):
    """Map a pytree of ParamSpec -> pytree of NamedSharding."""
    return jax.tree_util.tree_map(
        lambda s: s.sharding(mesh, rules), spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec))
