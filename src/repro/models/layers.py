"""Transformer building blocks: norms, RoPE/M-RoPE, GQA/MLA/SWA attention,
MLPs, embeddings -- pure functional JAX with name-based logical sharding.

Parameter shardings are resolved from leaf NAMES (single source of truth in
PARAM_LOGICAL below): any params tree built here can be mapped to
NamedShardings via `param_specs(params)` regardless of nesting or of the
extra leading layer axis introduced by scan-over-layers stacking.
"""

from __future__ import annotations

import math
import re
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.sharding import ParamSpec, aconstrain

# --------------------------------------------------------------------------
# Name -> logical axes registry. Arrays may carry extra LEADING axes (layer
# stacking); logical tuples are right-aligned and left-padded with None.
# --------------------------------------------------------------------------

import os as _os

# Perf iteration 1 (REPRO_OPT=1, see EXPERIMENTS.md §Perf): embeddings
# vocab-sharded ONLY. FSDP-sharding the d_model dim makes the unembed
# contraction partial-sum the full fp32 logits over the data axis (measured
# 8 GB AR + 8 GB AG per microbatch on llama3-405b); vocab-only sharding
# keeps logits tensor-sharded with no logits-sized collective at all.
# Baseline (REPRO_OPT=0): FSDP+vocab sharding, as swept for the tables.
_OPT = _os.environ.get("REPRO_OPT", "0") != "0"

_EMBED_LOGICAL = ([(r"^unembed$", (None, "tensor")),
                   (r"^embed$", ("tensor", None))] if _OPT else
                  [(r"^unembed$", ("fsdp", "tensor")),
                   (r"^embed$", ("tensor", "fsdp"))])

PARAM_LOGICAL = _EMBED_LOGICAL + [
    (r"pos_embed$", (None, "fsdp")),
    (r"w[qkv]$", ("fsdp", "tensor", None)),
    (r"b[qkv]$", ("tensor", None)),
    (r"wo$", ("tensor", None, "fsdp")),
    (r"w[13]$", ("fsdp", "tensor")),
    (r"w2$", ("tensor", "fsdp")),
    (r"wq_a$|wkv_a$", ("fsdp", None)),
    (r"wq_b$|wkv_b$", (None, "tensor", None)),
    (r"wo_mla$", ("tensor", None, "fsdp")),
    (r"router$", ("fsdp", None)),
    (r"we[13]$", ("expert", None, "fsdp")),
    (r"we2$", ("expert", "fsdp", None)),
    (r"w_gates$", ("fsdp", "tensor")),
    (r"w_ogate$", ("fsdp", "tensor", None)),
    (r"r_(z|i|f|o)$", ("tensor", None, None)),
    (r"w_(z|i|f|o)$", ("fsdp", "tensor", None)),
    (r"in_proj$", ("fsdp", "tensor")),
    (r"out_proj$", ("tensor", "fsdp")),
    (r"conv_w$", (None, "tensor")),
    (r"x_proj$", ("tensor", None)),
    (r"dt_proj$", (None, "tensor")),
    (r"a_log$", ("tensor", None)),
    (r"head_w$", ("fsdp", "tensor")),
    # everything else (norm scales, small biases, gate vectors): replicated
    (r".", ()),
]


def logical_for(name: str, ndim: int) -> tuple:
    for pat, logical in PARAM_LOGICAL:
        if re.search(pat, name):
            pad = ndim - len(logical)
            return (None,) * pad + tuple(logical)
    return (None,) * ndim


def param_specs(params) -> object:
    """Pytree of ParamSpec mirroring `params` (works on ShapeDtypeStructs)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    specs = []
    for path, leaf in flat:
        name = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
        specs.append(ParamSpec(logical_for(name, leaf.ndim)))
    return jax.tree_util.tree_unflatten(treedef, specs)


# --------------------------------------------------------------------------
# Initializers.
# --------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale_axis=None):
    """Normal init scaled by 1/sqrt(fan_in); scale_axis is the EXPLICIT
    fan-in value (defaults to shape[0])."""
    fan_in = shape[0] if scale_axis is None else scale_axis
    return (jax.random.normal(key, shape) / math.sqrt(fan_in)).astype(dtype)


# --------------------------------------------------------------------------
# Norms.
# --------------------------------------------------------------------------


def norm_init(cfg: ModelConfig, dim=None):
    dim = dim or cfg.d_model
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["nbias"] = jnp.zeros((dim,), jnp.float32)
    return p


_OPT = int(_os.environ.get("REPRO_OPT", "0") or 0)


def apply_norm(p, x, cfg: ModelConfig, eps=1e-6):
    if _OPT >= 4:
        # Perf iteration 4: statistics in f32, MULTIPLY in the compute dtype
        # -- keeps the residual stream free of f32 consumers so the
        # partitioner's psum stays bf16 (see EXPERIMENTS.md §Perf).
        xf = x.astype(jnp.float32)
        if cfg.norm == "layernorm":
            mu = xf.mean(-1, keepdims=True)
            var = xf.var(-1, keepdims=True)
            inv = jax.lax.rsqrt(var + eps)
            y = (x - mu.astype(x.dtype)) * inv.astype(x.dtype) \
                * p["scale"].astype(x.dtype) + p["nbias"].astype(x.dtype)
        else:
            var = (xf * xf).mean(-1, keepdims=True)
            y = x * jax.lax.rsqrt(var + eps).astype(x.dtype) \
                * p["scale"].astype(x.dtype)
        return y
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = xf.var(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["nbias"]
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary embeddings (+ Qwen2-VL multimodal M-RoPE).
# --------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def rope_sincos(pos: jax.Array, dim: int, theta: float):
    """pos (..., S) -> sin/cos (..., S, dim/2)."""
    ang = pos[..., None].astype(jnp.float32) * rope_freqs(dim, theta)
    return jnp.sin(ang), jnp.cos(ang)


def mrope_sincos(pos3: jax.Array, dim: int, theta: float, sections: tuple):
    """pos3 (..., S, 3) -> sin/cos (..., S, dim/2) with the dim/2 frequency
    slots split across (temporal, height, width) position streams."""
    assert sum(sections) == dim // 2, (sections, dim)
    sin, cos = rope_sincos(jnp.moveaxis(pos3, -1, 0), dim, theta)  # (3,...,S,d/2)
    idx = np.repeat(np.arange(3), np.asarray(sections))            # (d/2,)
    sel = jax.nn.one_hot(jnp.asarray(idx), 3, dtype=sin.dtype)     # (d/2, 3)
    pick = lambda t: jnp.einsum("t...f,ft->...f", t, sel)
    return pick(sin), pick(cos)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x (B, S, H, D); sin/cos (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:
        sin, cos = sin[None], cos[None]
    sin, cos = sin[:, :, None, :], cos[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Blockwise (flash-style) attention: online softmax over kv chunks.
# --------------------------------------------------------------------------


def _attn_scores_mask(qpos, kpos, window):
    m = kpos[None, :] <= qpos[:, None]
    if window:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def dot_attention(q, k, v, *, qpos, kpos, window=0, chunk=0,
                  kv_valid=None, softcap=0.0):
    """Grouped-query attention with absolute-position causal/window masking.

    q (B, S, H, D); k, v (B, T, KV, D); qpos (S,), kpos (T,) absolute
    positions; kv_valid optional (B, T) bool. Returns (B, S, H, D).
    """
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    DV = v.shape[-1]                     # may differ from D (MLA)
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, S, KV, G, D) * scale

    def scores_of(kc, kposc, validc):
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kc).astype(jnp.float32)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        m = _attn_scores_mask(qpos, kposc, window)
        if validc is not None:
            m = m[None, :, :] & validc[:, None, :]
            m = m[:, None, None]
        else:
            m = m[None, None, None]
        return jnp.where(m, s, -1e30)

    if not chunk or T <= chunk:
        s = scores_of(k, kpos, kv_valid)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bkgst,btkd->bskgd", p, v)
        return o.reshape(B, S, H, DV)

    n_chunks = T // chunk
    assert T % chunk == 0, (T, chunk)
    ks = k.reshape(B, n_chunks, chunk, KV, D)
    vs = v.reshape(B, n_chunks, chunk, KV, DV)
    kps = kpos.reshape(n_chunks, chunk)
    valids = None if kv_valid is None else kv_valid.reshape(B, n_chunks, chunk)

    def body(carry, xs):
        m_run, l_run, acc = carry
        kc, vc, kpc, vldc = xs
        s = scores_of(kc, kpc, vldc)                      # (B,KV,G,S,c)
        m_new = jnp.maximum(m_run, s.max(-1))
        corr = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_run = l_run * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(vc.dtype), vc).astype(jnp.float32)
        return (m_new, l_run, acc), None

    init = (jnp.full((B, KV, G, S), -jnp.inf, jnp.float32),
            jnp.zeros((B, KV, G, S), jnp.float32),
            jnp.zeros((B, KV, G, S, DV), jnp.float32))
    xs = (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0), kps,
          None if valids is None else jnp.moveaxis(valids, 1, 0))
    (m_run, l_run, acc), _ = jax.lax.scan(body, init, xs)
    o = acc / jnp.maximum(l_run, 1e-30)[..., None]
    return jnp.moveaxis(o, 3, 1).reshape(B, S, H, DV).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention layer (with SWA + decode caches).
# --------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig, dtype):
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.n_heads, hd), dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads, hd), dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads, hd), dtype),
        "wo": dense_init(ks[3], (cfg.n_heads, hd, cfg.d_model), dtype,
                         scale_axis=cfg.n_heads * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
    return p


def _rope_for(cfg: ModelConfig, pos, positions3=None):
    if cfg.rope_type == "none":
        return None
    if cfg.rope_type == "mrope":
        assert positions3 is not None
        return mrope_sincos(positions3, cfg.hd, cfg.rope_theta,
                            cfg.mrope_sections)
    return rope_sincos(pos, cfg.hd, cfg.rope_theta)


def attn_apply(p, x, cfg: ModelConfig, *, layer_window=0, cache=None,
               pos0=0, positions3=None):
    """x (B, S, D). cache None (train/prefill) or dict(k, v, kpos) for decode.
    Returns (y, new_cache)."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = aconstrain(q, "batch", None, "tensor", None)
    k = aconstrain(k, "batch", None, "tensor", None)
    v = aconstrain(v, "batch", None, "tensor", None)
    qpos = pos0 + jnp.arange(S)
    sc = _rope_for(cfg, qpos, positions3)
    if sc is not None:
        q = apply_rope(q, *sc)
        k = apply_rope(k, *sc)

    if cache is None:
        y = dot_attention(q, k, v, qpos=qpos, kpos=qpos,
                          window=layer_window, chunk=cfg.attn_chunk,
                          softcap=cfg.logit_softcap)
        new_cache = {"k": k, "v": v, "kpos": qpos}
    else:
        # decode: write this step's k/v at slot (ring for SWA layers)
        T = cache["k"].shape[1]
        slot = (pos0 % T) if layer_window else jnp.minimum(pos0, T - 1)
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        kp = jax.lax.dynamic_update_slice(cache["kpos"],
                                          qpos.astype(cache["kpos"].dtype),
                                          (slot,))
        valid = (kp <= pos0)
        if layer_window:
            valid &= kp > pos0 - layer_window
        y = dot_attention(q, ck, cv, qpos=qpos, kpos=kp, window=layer_window,
                          chunk=0, kv_valid=jnp.broadcast_to(valid, (B, T)),
                          softcap=cfg.logit_softcap)
        new_cache = {"k": ck, "v": cv, "kpos": kp}
    y = aconstrain(y, "batch", None, "tensor", None)
    y = jnp.einsum("bshk,hkd->bsd", y, p["wo"])
    y = aconstrain(y, "batch", None, None)
    return y, new_cache


def attn_cache_init(cfg: ModelConfig, batch, max_seq, layer_window, dtype):
    T = min(layer_window, max_seq) if layer_window else max_seq
    return {
        "k": jnp.zeros((batch, T, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, T, cfg.n_kv_heads, cfg.hd), dtype),
        "kpos": jnp.full((T,), jnp.iinfo(jnp.int32).max, jnp.int32),
    }


# --------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V3).
# --------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig, dtype):
    m: MLAConfig = cfg.mla
    ks = jax.random.split(key, 5)
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_a": dense_init(ks[0], (cfg.d_model, m.q_lora_rank), dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, cfg.n_heads, qk_dim), dtype),
        "wkv_a": dense_init(ks[2],
                            (cfg.d_model, m.kv_lora_rank + m.qk_rope_dim),
                            dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "wkv_b": dense_init(ks[3], (m.kv_lora_rank, cfg.n_heads,
                                    m.qk_nope_dim + m.v_dim), dtype),
        "wo_mla": dense_init(ks[4], (cfg.n_heads, m.v_dim, cfg.d_model),
                             dtype, scale_axis=cfg.n_heads * m.v_dim),
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps) * scale
    return y.astype(x.dtype)


def mla_apply(p, x, cfg: ModelConfig, *, cache=None, pos0=0):
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = _rms(x @ p["wq_a"], p["q_norm"])
    q = aconstrain(jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"]),
                   "batch", None, "tensor", None)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    kv_a = x @ p["wkv_a"]
    c_kv = _rms(kv_a[..., :m.kv_lora_rank], p["kv_norm"])
    k_rope = kv_a[..., m.kv_lora_rank:]                      # (B,S,rope)
    qpos = pos0 + jnp.arange(S)
    sin, cos = rope_sincos(qpos, m.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    k_rope = apply_rope(k_rope[:, :, None, :], sin, cos)[:, :, 0]
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)

    if cache is None:
        kv = aconstrain(jnp.einsum("bsr,rhn->bshn", c_kv, p["wkv_b"]),
                        "batch", None, "tensor", None)
        k_nope, v = kv[..., :m.qk_nope_dim], kv[..., m.qk_nope_dim:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, H, m.qk_rope_dim))], -1)
        qf = jnp.concatenate([q_nope, q_rope], -1)
        y = dot_attention(qf, k, v, qpos=qpos, kpos=qpos,
                          chunk=cfg.attn_chunk)
        new_cache = {"ckv": c_kv, "krope": k_rope, "kpos": qpos}
    else:
        # absorbed decode: score against the cached LATENTS directly
        T = cache["ckv"].shape[1]
        slot = jnp.minimum(pos0, T - 1)
        ckv = jax.lax.dynamic_update_slice(cache["ckv"], c_kv, (0, slot, 0))
        krp = jax.lax.dynamic_update_slice(cache["krope"], k_rope, (0, slot, 0))
        kp = jax.lax.dynamic_update_slice(
            cache["kpos"], qpos.astype(jnp.int32), (slot,))
        w_uk = p["wkv_b"][..., :m.qk_nope_dim]               # (r, h, nope)
        q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)
        s = (jnp.einsum("bshr,btr->bhst", q_abs, ckv)
             + jnp.einsum("bshk,btk->bhst", q_rope, krp)).astype(jnp.float32)
        s = s * scale
        valid = (kp <= pos0)[None, None, None, :]
        s = jnp.where(valid, s, -1e30)
        prob = jax.nn.softmax(s, -1).astype(x.dtype)
        o_lat = jnp.einsum("bhst,btr->bshr", prob, ckv)
        w_uv = p["wkv_b"][..., m.qk_nope_dim:]               # (r, h, v)
        y = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv)
        new_cache = {"ckv": ckv, "krope": krp, "kpos": kp}
    y = jnp.einsum("bshv,hvd->bsd", y, p["wo_mla"])
    y = aconstrain(y, "batch", None, None)
    return y, new_cache


def mla_cache_init(cfg: ModelConfig, batch, max_seq, dtype):
    m: MLAConfig = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_seq, m.qk_rope_dim), dtype),
        "kpos": jnp.full((max_seq,), jnp.iinfo(jnp.int32).max, jnp.int32),
    }


# --------------------------------------------------------------------------
# MLP.
# --------------------------------------------------------------------------

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def mlp_init(key, cfg: ModelConfig, dtype, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], (cfg.d_model, d_ff), dtype),
         "w2": dense_init(ks[1], (d_ff, cfg.d_model), dtype)}
    if cfg.mlp_gated:
        p["w3"] = dense_init(ks[2], (cfg.d_model, d_ff), dtype)
    if cfg.mlp_bias:
        p["mb1"] = jnp.zeros((d_ff,), dtype)
        p["mb2"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def mlp_apply(p, x, cfg: ModelConfig):
    act = _ACTS[cfg.mlp_act]
    h = aconstrain(x @ p["w1"], "batch", None, "tensor")
    if cfg.mlp_bias:
        h = h + p["mb1"]
    h = act(h)
    if cfg.mlp_gated:
        h = h * aconstrain(x @ p["w3"], "batch", None, "tensor")
    y = h @ p["w2"]
    y = aconstrain(y, "batch", None, None)
    if cfg.mlp_bias:
        y = y + p["mb2"]
    return y
