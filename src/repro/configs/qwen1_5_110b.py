"""Qwen1.5-110B: GQA kv=8 with QKV bias [hf:Qwen/Qwen1.5-110B]."""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=49152, vocab_size=152064,
        qkv_bias=True, rope_theta=1e6,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, qkv_bias=True, remat=False,
    )
