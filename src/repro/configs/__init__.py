"""Architecture config registry: --arch <id> resolution."""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, TrainConfig

ARCHS = [
    "xlstm-350m",
    "llama3-405b",
    "starcoder2-3b",
    "qwen1.5-110b",
    "command-r-plus-104b",
    "deepseek-moe-16b",
    "deepseek-v3-671b",
    "musicgen-medium",
    "hymba-1.5b",
    "qwen2-vl-7b",
    # paper-faithful FSL controllers
    "omniglot-conv4",
    "cub-resnet12",
]


def _module(arch: str):
    return importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))


def load_config(arch: str, smoke: bool = False) -> ModelConfig:
    m = _module(arch)
    return m.get_smoke_config() if smoke else m.get_config()


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch, shape) dry-run cell is runnable (DESIGN.md Sec. 4)."""
    if shape.name == "long_500k":
        sub_quadratic = cfg.family in ("ssm", "hybrid")
        if not sub_quadratic:
            return False, "skipped(full-attention arch at 500k context)"
    return True, ""
