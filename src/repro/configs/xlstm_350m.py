"""xLSTM-350M: sLSTM + mLSTM blocks, 7:1 ratio [arXiv:2405.04517].

24L d_model=1024 4H d_ff=0 (block-internal projections) vocab=50304.
Runs long_500k: O(1) recurrent decode state.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304,
        default_layer="mlstm", slstm_every=8,
        rope_type="none", tie_embeddings=True,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-smoke", family="ssm",
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab_size=256,
        default_layer="mlstm", slstm_every=4,
        rope_type="none", tie_embeddings=True, remat=False,
    )
