"""Paper-faithful Omniglot setup: Conv4 controller, 48-d embeddings,
200-way 10-shot, MTMC CL=32 -> 128K NAND strings (paper Sec. 4.1)."""
import dataclasses

from repro.core.avss import SearchConfig
from repro.core.mcam import MCAMConfig


@dataclasses.dataclass(frozen=True)
class FSLConfig:
    name: str
    controller: str
    embed_dim: int
    image_size: int
    channels: int
    n_way: int
    k_shot: int
    n_train_classes: int
    n_test_classes: int
    cl: int                      # paper code-word length for the dataset
    search: SearchConfig = dataclasses.field(default_factory=SearchConfig)


def get_config() -> FSLConfig:
    return FSLConfig(
        name="omniglot-conv4", controller="conv4", embed_dim=48,
        image_size=28, channels=1, n_way=200, k_shot=10,
        n_train_classes=964, n_test_classes=659, cl=32,
        search=SearchConfig(encoding="mtmc", cl=32, mode="avss",
                            mcam=MCAMConfig()),
    )


def get_smoke_config() -> FSLConfig:
    return FSLConfig(
        name="omniglot-conv4-smoke", controller="conv4", embed_dim=24,
        image_size=20, channels=1, n_way=8, k_shot=3,
        n_train_classes=30, n_test_classes=12, cl=8,
        search=SearchConfig(encoding="mtmc", cl=8, mode="avss",
                            mcam=MCAMConfig()),
    )
