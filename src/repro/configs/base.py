"""Model / training / shape configuration schema.

Every assigned architecture file under repro/configs/ exports
``get_config()`` (the exact published spec) and ``get_smoke_config()`` (a
reduced same-family config for CPU smoke tests). Shapes are the four assigned
input-shape cells; `kind` decides which step gets lowered in the dry-run.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    n_shared: int
    top_k: int
    d_ff: int                      # per-expert FFN width
    capacity_factor: float = 1.25
    groups: int = 1                # dispatch groups (launcher sets >= dp shards)
    aux_weight: float = 0.01
    first_dense_layers: int = 0
    dense_d_ff: int = 0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: float = 2.0
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # layer pattern: 'attn' | 'mla' | 'swa' | 'mlstm' | 'slstm' | 'hymba'
    default_layer: str = "attn"
    global_attn_layers: tuple = () # indices forced to full 'attn' (hymba)
    slstm_every: int = 0           # xlstm: every k-th layer is sLSTM
    window: int = 0                # sliding-window size for 'swa' layers
    # flavour flags
    qkv_bias: bool = False
    mlp_bias: bool = False
    mlp_gated: bool = True         # SwiGLU vs plain 2-matrix MLP
    mlp_act: str = "silu"
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    parallel_block: bool = False   # command-r style attn || mlp
    tie_embeddings: bool = False
    rope_type: str = "rope"        # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: tuple = ()     # head_dim/2 split over (t, h, w)
    input_mode: str = "tokens"     # tokens | embeddings (audio/vlm stubs)
    pos_embed: str = "none"        # none | sinusoidal (additive)
    logit_softcap: float = 0.0
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    # execution
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk: int = 1024         # blockwise attention kv-chunk (0 = never)
    scan_layers: bool = True
    # dry-run cost calibration: direct (type, is_moe, count) group override
    layer_groups_override: tuple = ()

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_types(self) -> tuple:
        out = []
        for i in range(self.n_layers):
            t = self.default_layer
            if self.slstm_every and (i + 1) % self.slstm_every == 0:
                t = "slstm"
            if i in self.global_attn_layers:
                # full-window variant of the default layer (hymba keeps its
                # parallel mamba branch; swa models fall back to full attn)
                t = "hymba_g" if self.default_layer == "hymba" else "attn"
            out.append(t)
        return tuple(out)

    def moe_layers(self) -> tuple:
        if self.moe is None:
            return tuple([False] * self.n_layers)
        k = self.moe.first_dense_layers
        return tuple([i >= k for i in range(self.n_layers)])

    def layer_groups(self) -> tuple:
        """Consecutive runs of identical (layer_type, is_moe) -> scan groups.
        Returns tuple of (layer_type, is_moe, count)."""
        if self.layer_groups_override:
            return tuple(tuple(g) for g in self.layer_groups_override)
        kinds = list(zip(self.layer_types(), self.moe_layers()))
        groups = []
        for t, m in kinds:
            if groups and groups[-1][0] == t and groups[-1][1] == m:
                groups[-1][2] += 1
            else:
                groups.append([t, m, 1])
        return tuple((t, m, c) for t, m, c in groups)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode
    microbatch: int = 0            # 0 -> global_batch (no accumulation)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    optimizer: str = "adamw"       # adamw | adafactor | adamw8bit
    state_dtype: str = "float32"   # moment dtype for adamw
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    grad_compression: str = "none" # none | int8
