"""Qwen2-VL-7B backbone: M-RoPE over (temporal, height, width) position
streams, dynamic-resolution vision frontend STUBBED (input_specs() provides
patch embeddings + 3D positions) [arXiv:2409.12191]."""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab_size=152064,
        qkv_bias=True, rope_type="mrope", mrope_sections=(16, 24, 24),
        rope_theta=1e6, input_mode="embeddings",
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256,
        qkv_bias=True, rope_type="mrope", mrope_sections=(4, 2, 2),
        input_mode="embeddings", remat=False,
    )
