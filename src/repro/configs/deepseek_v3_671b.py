"""DeepSeek-V3 671B: MLA (q_lora 1536 / kv_lora 512 / rope 64),
1 shared + 256 routed top-8 fine-grained experts, first 3 layers dense
[arXiv:2412.19437]. Assigned d_ff=2048 is the per-expert width; dense
layers use the published 18432. MTP head available via train options."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=2048, vocab_size=129280,
        default_layer="mla",
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
        moe=MoEConfig(n_routed=256, n_shared=1, top_k=8, d_ff=2048,
                      first_dense_layers=3, dense_d_ff=18432, groups=16),
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab_size=256,
        default_layer="mla",
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_dim=16, qk_rope_dim=8, v_dim=16),
        moe=MoEConfig(n_routed=8, n_shared=1, top_k=2, d_ff=64,
                      first_dense_layers=1, dense_d_ff=128, groups=1),
        remat=False,
    )
