"""StarCoder2-3B: GQA kv=2, RoPE, LayerNorm, plain-GELU MLP, biases
[arXiv:2402.19173; hf bigcode/starcoder2-3b]."""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b", family="dense",
        n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
        d_ff=12288, vocab_size=49152,
        norm="layernorm", mlp_gated=False, mlp_act="gelu",
        qkv_bias=True, mlp_bias=True, tie_embeddings=True,
        rope_theta=1e6,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256,
        norm="layernorm", mlp_gated=False, mlp_act="gelu",
        qkv_bias=True, mlp_bias=True, tie_embeddings=True, remat=False,
    )
