"""Paper-faithful CUB setup: ResNet12 controller, 480-d embeddings,
50-way 5-shot, MTMC CL=25 -> ~125K NAND strings (paper Sec. 4.1)."""
from repro.configs.omniglot_conv4 import FSLConfig

from repro.core.avss import SearchConfig
from repro.core.mcam import MCAMConfig


def get_config() -> FSLConfig:
    return FSLConfig(
        name="cub-resnet12", controller="resnet12", embed_dim=480,
        image_size=84, channels=3, n_way=50, k_shot=5,
        n_train_classes=100, n_test_classes=50, cl=25,
        search=SearchConfig(encoding="mtmc", cl=25, mode="avss",
                            mcam=MCAMConfig()),
    )


def get_smoke_config() -> FSLConfig:
    return FSLConfig(
        name="cub-resnet12-smoke", controller="resnet12", embed_dim=32,
        image_size=24, channels=3, n_way=6, k_shot=2,
        n_train_classes=20, n_test_classes=8, cl=6,
        search=SearchConfig(encoding="mtmc", cl=6, mode="avss",
                            mcam=MCAMConfig()),
    )
