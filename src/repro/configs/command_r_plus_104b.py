"""Command R+ 104B: GQA kv=8, no linear biases, PARALLEL attn+FFN block,
LayerNorm, tied embeddings [hf:CohereForAI/c4ai-command-r-plus]."""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b", family="dense",
        n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
        d_ff=33792, vocab_size=256000,
        norm="layernorm", parallel_block=True, tie_embeddings=True,
        rope_theta=75e6,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256,
        norm="layernorm", parallel_block=True, tie_embeddings=True,
        remat=False,
    )
