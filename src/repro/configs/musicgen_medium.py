"""MusicGen-medium backbone: decoder-only over EnCodec tokens
[arXiv:2306.05284]. Modality frontend is a STUB: input_specs() provides
precomputed frame embeddings; the head predicts the 2048-entry codebook.
Sinusoidal positions, LayerNorm, plain-GELU MLP (AudioCraft style)."""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
        d_ff=6144, vocab_size=2048,
        norm="layernorm", mlp_gated=False, mlp_act="gelu",
        rope_type="none", pos_embed="sinusoidal",
        input_mode="embeddings",
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=128,
        norm="layernorm", mlp_gated=False, mlp_act="gelu",
        rope_type="none", pos_embed="sinusoidal",
        input_mode="embeddings", remat=False,
    )
