"""DeepSeekMoE-16B: fine-grained experts, 2 shared + 64 routed top-6,
first layer dense [arXiv:2401.06066]. Assigned d_ff=1408 is the per-expert
width; the first dense layer uses the published 10944."""
from repro.configs.base import ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=102400,
        moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_ff=1408,
                      first_dense_layers=1, dense_d_ff=10944, groups=16),
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab_size=256,
        moe=MoEConfig(n_routed=8, n_shared=2, top_k=2, d_ff=96,
                      first_dense_layers=1, dense_d_ff=256, groups=1),
        remat=False,
    )
