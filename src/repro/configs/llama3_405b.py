"""Llama-3.1 405B: GQA kv=8, 128k vocab, RoPE theta 5e5 [arXiv:2407.21783]."""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
        d_ff=53248, vocab_size=128256,
        rope_theta=500000.0,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, rope_theta=500000.0, remat=False,
    )
