"""Hymba-1.5B: parallel attention + Mamba heads per layer, SWA everywhere
except 3 full-attention layers (first/middle/last), ssm_state=16
[arXiv:2411.13676]. vocab 32001."""
from repro.configs.base import ModelConfig, SSMConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab_size=32001,
        default_layer="hymba", global_attn_layers=(0, 15, 31),
        window=1024, ssm=SSMConfig(d_state=16, d_conv=4, expand=2.0),
        tie_embeddings=True,
    )


def get_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256,
        default_layer="hymba", global_attn_layers=(0, 3),
        window=16, ssm=SSMConfig(d_state=8, d_conv=4, expand=2.0),
        tie_embeddings=True, remat=False,
    )
