"""Procedural few-shot image datasets (offline container => no downloads).

* OmniglotLike -- handwritten-character analogue: each class is a fixed set
  of 3-6 strokes (random polylines); instances apply affine jitter + pixel
  noise before rasterisation. Single channel, paper geometry 28x28,
  964 train / 659 test classes available.
* CUBLike -- natural-image analogue: each class is a mixture of coloured
  2D Gaussian blobs over a textured background; instances jitter blob
  positions/scales. 3 channels, 84x84.

Both expose  class_images(class_id, n, rng_seed)  and an EpisodeSampler
producing N-way K-shot episodes with disjoint support/query instances.
Deterministic given (seed, episode index) => resumable meta-training.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def _rasterize_strokes(strokes, size, thickness=1.2):
    """strokes: list of (P, 2) polyline points in [0,1]^2 -> (size, size)."""
    img = np.zeros((size, size), np.float32)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32) / (size - 1)
    for pts in strokes:
        for a, b in zip(pts[:-1], pts[1:]):
            seg = b - a
            L = max(float(np.hypot(*seg)), 1e-6)
            n = max(int(L * size * 2), 2)
            ts = np.linspace(0, 1, n)[:, None]
            centers = a[None] + ts * seg[None]
            for c in centers:
                d2 = (yy - c[1]) ** 2 + (xx - c[0]) ** 2
                img += np.exp(-d2 * (size * thickness) ** 2 / 2)
    return np.clip(img, 0, 1)


class OmniglotLike:
    def __init__(self, n_classes: int, image_size: int = 28, seed: int = 0):
        self.n_classes = n_classes
        self.size = image_size
        self.seed = seed

    def _class_strokes(self, cid: int):
        rng = np.random.RandomState((self.seed * 9_999_991 + cid) % 2**31)
        strokes = []
        for _ in range(rng.randint(3, 7)):
            npts = rng.randint(2, 5)
            strokes.append(rng.uniform(0.12, 0.88, size=(npts, 2)))
        return strokes

    def class_images(self, cid: int, n: int, rng_seed: int) -> np.ndarray:
        """(n, H, W, 1) float32 instances of class cid."""
        base = self._class_strokes(cid)
        rng = np.random.RandomState((rng_seed * 7_654_321 + cid) % 2**31)
        out = np.zeros((n, self.size, self.size, 1), np.float32)
        for i in range(n):
            ang = rng.uniform(-0.25, 0.25)
            scale = rng.uniform(0.9, 1.1)
            shift = rng.uniform(-0.06, 0.06, size=2)
            R = scale * np.array([[np.cos(ang), -np.sin(ang)],
                                  [np.sin(ang), np.cos(ang)]])
            strokes = [(pts - 0.5) @ R.T + 0.5 + shift for pts in base]
            img = _rasterize_strokes(strokes, self.size)
            img += rng.randn(self.size, self.size).astype(np.float32) * 0.05
            out[i, :, :, 0] = np.clip(img, 0, 1)
        return out


class CUBLike:
    def __init__(self, n_classes: int, image_size: int = 84, seed: int = 0):
        self.n_classes = n_classes
        self.size = image_size
        self.seed = seed

    def class_images(self, cid: int, n: int, rng_seed: int) -> np.ndarray:
        crng = np.random.RandomState((self.seed * 31_337 + cid) % 2**31)
        k = crng.randint(3, 6)
        mus = crng.uniform(0.2, 0.8, size=(k, 2))
        sig = crng.uniform(0.05, 0.18, size=(k,))
        col = crng.uniform(0.1, 1.0, size=(k, 3))
        freq = crng.uniform(2, 8, size=2)
        rng = np.random.RandomState((rng_seed * 123_457 + cid) % 2**31)
        yy, xx = np.mgrid[0:self.size, 0:self.size].astype(np.float32)
        yy, xx = yy / self.size, xx / self.size
        out = np.zeros((n, self.size, self.size, 3), np.float32)
        for i in range(n):
            img = 0.15 * (1 + np.sin(freq[0] * np.pi * xx)
                          * np.sin(freq[1] * np.pi * yy))[..., None]
            img = np.repeat(img, 3, axis=-1)
            for j in range(k):
                m = mus[j] + rng.uniform(-0.08, 0.08, size=2)
                s = sig[j] * rng.uniform(0.85, 1.15)
                blob = np.exp(-((xx - m[0]) ** 2 + (yy - m[1]) ** 2)
                              / (2 * s * s))
                img += blob[..., None] * col[j]
            img += rng.randn(self.size, self.size, 3).astype(np.float32) * 0.04
            out[i] = np.clip(img, 0, 1)
        return out


@dataclasses.dataclass
class Episode:
    support_images: np.ndarray
    support_labels: np.ndarray   # in [0, n_way)
    query_images: np.ndarray
    query_labels: np.ndarray
    n_way: int
    class_ids: np.ndarray        # global class ids per way


class EpisodeSampler:
    def __init__(self, dataset, class_ids, n_way, k_shot, n_query=5, seed=0):
        self.ds = dataset
        self.class_ids = np.asarray(class_ids)
        self.n_way, self.k_shot, self.n_query = n_way, k_shot, n_query
        self.seed = seed

    def episode(self, index: int) -> Episode:
        rng = np.random.RandomState((self.seed * 48_611 + index) % 2**31)
        ways = rng.choice(self.class_ids, size=self.n_way, replace=False)
        s_imgs, s_lab, q_imgs, q_lab = [], [], [], []
        for w, cid in enumerate(ways):
            imgs = self.ds.class_images(int(cid), self.k_shot + self.n_query,
                                        rng_seed=index + 1)
            s_imgs.append(imgs[:self.k_shot])
            q_imgs.append(imgs[self.k_shot:])
            s_lab += [w] * self.k_shot
            q_lab += [w] * self.n_query
        return Episode(
            support_images=np.concatenate(s_imgs),
            support_labels=np.asarray(s_lab, np.int32),
            query_images=np.concatenate(q_imgs),
            query_labels=np.asarray(q_lab, np.int32),
            n_way=self.n_way, class_ids=ways)


def pretrain_batch(dataset, class_ids, batch: int, step: int, seed: int = 0):
    """Flat classification batches for HAT stage 1."""
    rng = np.random.RandomState((seed * 104_729 + step) % 2**31)
    cids = rng.choice(class_ids, size=batch)
    imgs, labels = [], []
    for c in cids:
        imgs.append(dataset.class_images(int(c), 1, rng_seed=step + 31)[0])
        labels.append(int(np.where(class_ids == c)[0][0]))
    return {"image": np.stack(imgs), "label": np.asarray(labels, np.int32)}
