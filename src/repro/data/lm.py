"""LM token pipeline: deterministic, host-sharded, step-addressable.

Resumability/fault-tolerance contract: `batch_for_step(step)` is a pure
function of (seed, step, host shard), so restarting from a checkpoint at step
k replays exactly the batches k, k+1, ... with no data-loader state to
persist, and elastic restarts onto a different host count re-shard cleanly
(shard by global example index, not by host-local counters).

Two sources:
  * SyntheticLM -- structured random tokens (Zipf unigrams + per-document
    repeated motifs) so small models show real loss decrease.
  * BinTokenSource -- memory-mapped .bin of uint16/uint32 tokens for real
    corpora (numpy memmap; no torch dependency).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    motif_len: int = 8
    motifs_per_doc: int = 4


class SyntheticLM:
    """Zipf background + repeated motifs => predictable structure."""

    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = p / p.sum()
        self._motif_bank = rng.randint(
            0, cfg.vocab_size, size=(256, cfg.motif_len)).astype(np.int32)

    def _example(self, idx: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.RandomState((cfg.seed * 1_000_003 + idx) % 2**31)
        toks = rng.choice(cfg.vocab_size, size=cfg.seq_len + 1,
                          p=self._p).astype(np.int32)
        for _ in range(cfg.motifs_per_doc):
            m = self._motif_bank[rng.randint(256)]
            for _ in range(3):  # motif repeats inside the doc -> learnable
                s = rng.randint(0, cfg.seq_len + 1 - cfg.motif_len)
                toks[s:s + cfg.motif_len] = m
        return toks

    def batch_for_step(self, step: int, host_index: int = 0,
                       host_count: int = 1) -> dict[str, np.ndarray]:
        cfg = self.cfg
        per_host = cfg.global_batch // host_count
        base = step * cfg.global_batch + host_index * per_host
        ex = np.stack([self._example(base + i) for i in range(per_host)])
        return {"tokens": ex[:, :-1], "labels": ex[:, 1:]}


class BinTokenSource:
    """Memory-mapped pre-tokenized corpus (uint16 or uint32 .bin)."""

    def __init__(self, path: str, cfg: LMDataConfig, dtype=np.uint16):
        self.cfg = cfg
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.n_examples = (len(self.data) - 1) // cfg.seq_len

    def batch_for_step(self, step, host_index=0, host_count=1):
        cfg = self.cfg
        per_host = cfg.global_batch // host_count
        base = step * cfg.global_batch + host_index * per_host
        idx = (base + np.arange(per_host)) % self.n_examples
        tok = np.stack([
            self.data[i * cfg.seq_len: i * cfg.seq_len + cfg.seq_len + 1]
            for i in idx]).astype(np.int32)
        return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}


def embedding_batch_for_step(step: int, batch: int, seq: int, d_model: int,
                             vocab: int, seed: int = 0, mrope: bool = False):
    """Stub-frontend batches (audio/vlm archs): deterministic embeddings in
    place of token ids + (optionally) 3D M-RoPE positions."""
    rng = np.random.RandomState((seed * 7_777_777 + step) % 2**31)
    out = {
        "embeddings": rng.randn(batch, seq, d_model).astype(np.float32) * 0.02,
        "labels": rng.randint(0, vocab, size=(batch, seq)).astype(np.int32),
    }
    if mrope:
        t = np.arange(seq)
        hw = int(np.sqrt(seq)) + 1
        pos3 = np.stack([t, t // hw, t % hw], -1)
        out["positions3"] = np.broadcast_to(
            pos3[None], (batch, seq, 3)).astype(np.int32)
    return out
