"""CLI for the contract guard: run / lint / diff / cost / cost-diff
(see package docstring).

`run` and `cost` force an 8-device host platform BEFORE importing jax,
so the sharded and multi-shard-write cells compile in-process on any
machine (the same trick the multi-device tests use via subprocess).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_REPORT = os.path.join("results", "contract_report.json")
DEFAULT_RESOURCES = os.path.join("results", "resource_report.json")


def _force_host_devices() -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count=8").strip()


def _cmd_run(args: argparse.Namespace) -> int:
    _force_host_devices()
    from repro.analysis import registry

    report = registry.run_cells()
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
    s = report["summary"]
    print(f"contract report: {s['pass']} pass, {s['fail']} fail, "
          f"{s['error']} error, {s['skip']} skip -> {args.out}")
    bad = [r for r in report["cells"] if r["status"] in ("fail", "error")]
    for r in bad:
        print(f"  {r['status'].upper()} {r['entry']} "
              f"{json.dumps(r['config'], sort_keys=True)} "
              f"[{r['invariant']}] {r['detail']}")
        for line in r["matched"]:
            print(f"    | {line}")
    return 1 if bad else 0


def _cmd_lint(args: argparse.Namespace) -> int:
    from repro.analysis import lint

    paths = args.paths or [os.path.join("src", "repro")]
    findings = lint.lint_paths(paths)
    for f in findings:
        print(f.format())
    print(f"lint: {len(findings)} finding(s) over {len(paths)} path(s)")
    return 1 if findings else 0


def _failures(report: dict) -> set[str]:
    return {f"{r['entry']}|{json.dumps(r['config'], sort_keys=True)}"
            f"|{r['invariant']}"
            for r in report["cells"] if r["status"] in ("fail", "error")}


def _cmd_diff(args: argparse.Namespace) -> int:
    with open(args.old, encoding="utf-8") as fh:
        old = json.load(fh)
    with open(args.new, encoding="utf-8") as fh:
        new = json.load(fh)
    fresh = sorted(_failures(new) - _failures(old))
    fixed = sorted(_failures(old) - _failures(new))
    for key in fixed:
        print(f"fixed: {key}")
    for key in fresh:
        print(f"NEW FAILURE: {key}")
    print(f"diff: {len(fresh)} new failure(s), {len(fixed)} fixed")
    return 1 if fresh else 0


def _cmd_cost(args: argparse.Namespace) -> int:
    _force_host_devices()
    from repro.analysis import cost

    report = cost.resource_report()
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
    s = report["summary"]
    print(f"resource report: {s['ok']} route(s) ok, {s['skip']} skip, "
          f"{s['error']} error -> {args.out}")
    bad = [r for r in report["routes"] if r["status"] == "error"]
    for r in bad:
        print(f"  ERROR {r['entry']} "
              f"{json.dumps(r['config'], sort_keys=True)} {r['detail']}")
    return 1 if bad else 0


def _cmd_cost_diff(args: argparse.Namespace) -> int:
    from repro.analysis import cost

    with open(args.old, encoding="utf-8") as fh:
        old = json.load(fh)
    with open(args.new, encoding="utf-8") as fh:
        new = json.load(fh)
    d = cost.diff_resource_reports(old, new, rtol=args.rtol)
    for key in d["missing"]:
        print(f"MISSING ROUTE: {key}")
    for row in d["drifted"]:
        rel = f" ({row['rel']:+.1%})" if row["rel"] is not None else ""
        print(f"DRIFT: {row['route']} {row['field']} "
              f"{row['old']} -> {row['new']}{rel}")
    for key in d["added"]:
        print(f"added: {key}")
    print(f"cost-diff: {len(d['drifted'])} drift(s), "
          f"{len(d['missing'])} missing, {len(d['added'])} added "
          f"(rtol={args.rtol})")
    return 1 if d["drifted"] or d["missing"] else 0


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = parser.add_subparsers(dest="cmd", required=True)
    p_run = sub.add_parser("run", help="compile + check every contract cell")
    p_run.add_argument("--out", default=DEFAULT_REPORT)
    p_run.set_defaults(fn=_cmd_run)
    p_lint = sub.add_parser("lint", help="repo-specific AST lint over src/")
    p_lint.add_argument("paths", nargs="*")
    p_lint.set_defaults(fn=_cmd_lint)
    p_diff = sub.add_parser("diff",
                            help="compare two reports; new failures = red")
    p_diff.add_argument("old")
    p_diff.add_argument("new")
    p_diff.set_defaults(fn=_cmd_diff)
    p_cost = sub.add_parser(
        "cost", help="static FLOPs/HBM resource row per registry route")
    p_cost.add_argument("--out", default=DEFAULT_RESOURCES)
    p_cost.set_defaults(fn=_cmd_cost)
    p_cdiff = sub.add_parser(
        "cost-diff",
        help="compare two resource reports; drift or lost routes = red")
    p_cdiff.add_argument("old")
    p_cdiff.add_argument("new")
    p_cdiff.add_argument("--rtol", type=float, default=0.05,
                         help="relative drift tolerance per field "
                              "(default 0.05; jit_entries is exact)")
    p_cdiff.set_defaults(fn=_cmd_cost_diff)
    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
