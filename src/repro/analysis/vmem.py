"""Symbolic VMEM model of the fused shortlist kernel (resource oracle 3b).

`kernels/shortlist.lut_shortlist_pallas` declares its per-grid-step
working set entirely through BlockSpecs, so the VMEM footprint of a
tiling config is a CLOSED-FORM function of the knobs -- no compile, no
TPU. This module mirrors the wrapper's width arithmetic exactly (same
kp rounding, same packed-width query padding, same tile_n power-of-two
rounding) and prices the resident blocks:

    q block     (tile_b, W)       query one-hots; W is the streamed
                                  query width (packed: padded to dp*wpi)
    s block     (tile_n, S)       projection tile: (tile_n, 4d) in the
                                  operand dtype, or (tile_n, dp) int32
                                  bit-packed
    pen block   (1, tile_n) f32   row-penalty stream (masked stores)
    out blocks  2 x (tile_b, kp)  running top-k buffer (f32 + int32)
    scratch                       the sort's live vectors: the
                                  (tile_b, tile_n) distance block and
                                  its row-index iota, times the copies
                                  a compare-exchange stage keeps live,
                                  plus the merge's (tile_b, kp) pairs

    total = 2*(q + s + pen)       double-buffered input streams
          + 2*out                 revisited output block, both buffers
          + scratch

Validated against interpret-mode `memory_analysis()` on a config sweep
(tests/test_vmem.py): on a single-tile grid the jitted call's
argument + output bytes equal the model's single-buffered block bytes
within the model's own `padding_slack_bytes` (query width pad, kp > k
output pad, f32 penalty stream vs the caller's bool row mask) -- and
EXACTLY for unpacked, unmasked, native-path configs.

`validate_config` is the static gate: benchmarks/autotune_shortlist.py
rejects sweep configs whose estimate exceeds the 16 MiB TPU VMEM budget
BEFORE timing anything, so a TPU autotune session cannot OOM mid-sweep.
"""

from __future__ import annotations

import dataclasses

from repro.kernels.shortlist import LANE, _pow2_at_least

#: per-core VMEM budget the gate enforces (TPU v4/v5 generations).
TPU_VMEM_BYTES = 16 * 2 ** 20

#: live (distance, index) vector-pair copies during a bitonic
#: compare-exchange stage: the block itself plus the rolled partner
#: values (kernels/shortlist._cmpex materialises pd/pi next to d/i).
SORT_LIVE_PAIRS = 2

_PAIR_BYTES = 4 + 4                    # f32 distance + int32 row index


@dataclasses.dataclass(frozen=True)
class VmemEstimate:
    """Closed-form per-tile VMEM footprint of one shortlist config.

    All byte fields derive from the BlockSpecs of
    `kernels/shortlist.lut_shortlist_pallas` (module docstring has the
    formula). `io_block_bytes` is the single-buffered operand + output
    block sum -- what interpret-mode memory_analysis measures as
    argument + output bytes on a single-tile grid; `total_bytes` is the
    double-buffered budget number the gate compares to TPU_VMEM_BYTES;
    `padding_slack_bytes` bounds the model-vs-measured gap attributable
    to pure padding.
    """

    tile_b: int
    tile_n: int                        # effective: power of two >= kp
    kp: int                            # internal top-k buffer width
    q_block_bytes: int
    s_block_bytes: int
    pen_block_bytes: int
    out_block_bytes: int
    scratch_bytes: int
    io_block_bytes: int
    total_bytes: int
    padding_slack_bytes: int


@dataclasses.dataclass(frozen=True)
class ConfigCheck:
    """Verdict of `validate_config`: ok, the estimate behind it, the
    budget it was held against, and a human-readable reason when not ok.
    """

    ok: bool
    estimate: VmemEstimate
    budget_bytes: int
    reason: str


def shortlist_vmem(tile_b: int, tile_n: int, k: int, *, width: int,
                   k_pad: int = LANE, pack_bits: int | None = None,
                   q_dtype_bytes: int = 4, masked: bool = False,
                   use_network: bool = True) -> VmemEstimate:
    """Per-tile VMEM bytes of `lut_shortlist_pallas` for one config.

    width: the logical one-hot query width 4*d (the kernel's K).
    q_dtype_bytes: bytes/element of the query operand as passed (2 for
    bf16, 4 for f32); the model applies the same f32 forcing the
    wrapper does for pack_bits > 8. Assumes B >= tile_b and
    N >= tile_n -- the autotune/serving regime; the wrapper shrinks
    tiles otherwise, which only lowers the footprint.
    """
    if use_network:
        # bitonic stages need power-of-two runs >= the lane width
        kp = _pow2_at_least(max(k, k_pad, 1))
    else:
        kp = max(k, 1)
    tile_n_eff = max(_pow2_at_least(max(tile_n, 1)), kp)
    if pack_bits is not None:
        assert pack_bits in (4, 8, 16, 32), pack_bits
        wpi = 32 // pack_bits
        dp = -(-width // wpi)          # ceil: packed projection columns
        q_width = dp * wpi             # wrapper pads the query up to this
        q_el = 4 if pack_bits > 8 else q_dtype_bytes
        s_block = tile_n_eff * dp * 4  # int32 packed words
    else:
        q_width = width
        q_el = q_dtype_bytes
        s_block = tile_n_eff * width * q_dtype_bytes
    q_block = tile_b * q_width * q_el
    pen_block = tile_n_eff * 4 if masked else 0
    out_block = tile_b * kp * _PAIR_BYTES
    live = SORT_LIVE_PAIRS if use_network else 1
    scratch = live * _PAIR_BYTES * tile_b * tile_n_eff \
        + (2 * _PAIR_BYTES * tile_b * kp if use_network else 0)
    io = q_block + s_block + pen_block + out_block
    total = 2 * (q_block + s_block + pen_block) + 2 * out_block + scratch
    slack = ((q_width - width) * tile_b * q_el          # query width pad
             + pen_block                                # f32 penalty stream
             + (tile_n_eff if masked else 0)            # caller's bool mask
             + (kp - k) * tile_b * _PAIR_BYTES)         # kp > k output pad
    return VmemEstimate(tile_b=tile_b, tile_n=tile_n_eff, kp=kp,
                        q_block_bytes=q_block, s_block_bytes=s_block,
                        pen_block_bytes=pen_block,
                        out_block_bytes=out_block, scratch_bytes=scratch,
                        io_block_bytes=io, total_bytes=total,
                        padding_slack_bytes=slack)


def validate_config(tile_b: int, tile_n: int, k: int, *, width: int,
                    k_pad: int = LANE, pack_bits: int | None = None,
                    q_dtype_bytes: int = 4, masked: bool = False,
                    use_network: bool = True,
                    budget_bytes: int = TPU_VMEM_BYTES) -> ConfigCheck:
    """Static accept/reject of one tiling config against the VMEM budget.

    The gate models the COMPILED TPU lowering (use_network=True, bitonic
    kp padding) by default -- the only target where the budget exists;
    interpret mode has no VMEM to exhaust. Callers reject before ever
    lowering the config, so an oversized tile can never OOM a sweep.
    """
    est = shortlist_vmem(tile_b, tile_n, k, width=width, k_pad=k_pad,
                         pack_bits=pack_bits, q_dtype_bytes=q_dtype_bytes,
                         masked=masked, use_network=use_network)
    if est.total_bytes > budget_bytes:
        return ConfigCheck(
            ok=False, estimate=est, budget_bytes=budget_bytes,
            reason=(f"estimated {est.total_bytes} B VMEM/tile exceeds the "
                    f"{budget_bytes} B budget (s block "
                    f"{est.s_block_bytes} B, scratch "
                    f"{est.scratch_bytes} B, out {est.out_block_bytes} B)"))
    return ConfigCheck(ok=True, estimate=est, budget_bytes=budget_bytes,
                       reason="")
