"""Static-analysis contract guard: HLO contract registry + repo AST lint.

Two passes, one CLI (`python -m repro.analysis`):

  run    compile every registered (invariant x entry-point x config) cell
         and check the compiled HLO (repro/analysis/registry.py,
         hlo_contracts.py); writes results/contract_report.json.
  lint   repo-specific AST rules over src/ (repro/analysis/lint.py).
  diff   compare two contract reports; new failures exit non-zero.

The test suite asserts its HLO expectations through the same
`hlo_contracts.assert_*` helpers the registry checks with, so every
invariant has exactly ONE spelling.
"""

from repro.analysis import hlo_contracts  # noqa: F401
