"""Static-analysis contract guard: HLO contracts, AST lint, resources.

Three passes, one CLI (`python -m repro.analysis`):

  run        compile every registered (invariant x entry-point x config)
             cell and check the compiled HLO (repro/analysis/registry.py,
             hlo_contracts.py); writes results/contract_report.json.
  lint       repo-specific AST rules over src/ (repro/analysis/lint.py).
  diff       compare two contract reports; new failures exit non-zero.
  cost       the resource oracle (repro/analysis/cost.py): one static
             {flops, hbm_bytes_read/written, temp_bytes, peak_bytes,
             jit_entries} row per registry route, derived from
             cost_analysis()/memory_analysis() + an HLO op census;
             writes results/resource_report.json.
  cost-diff  compare two resource reports against a relative tolerance;
             drift or a lost route exits non-zero (CI gates pushes
             against the committed RESOURCES_baseline.json).

repro/analysis/vmem.py is the symbolic VMEM side of the oracle: a
closed-form per-tile footprint of the fused shortlist kernel, used by
benchmarks/autotune_shortlist.py to reject over-budget tile configs
before a sweep ever lowers them.

The test suite asserts its HLO expectations through the same
`hlo_contracts.assert_*` helpers the registry checks with, so every
invariant has exactly ONE spelling -- and every
cost_analysis()/memory_analysis() read goes through cost.py (the
`cost-call` lint rule enforces it).
"""

from repro.analysis import hlo_contracts  # noqa: F401
