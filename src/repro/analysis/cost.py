"""One cost model for the repo: static FLOPs / HBM / memory extraction.

Pass 3 of the contract guard -- the *resource oracle*. Everything that
reads ``compiled.cost_analysis()`` / ``compiled.memory_analysis()`` or
parses cost-bearing ops out of HLO text lives HERE, one spelling,
enforced by the `cost-call` lint rule (repro/analysis/lint.py): a direct
call outside repro.analysis is a lint finding.

Three layers:

* Extraction over one compiled program: `compiled_cost`, `hbm_rw_bytes`,
  `compiled_memory`, `temp_bytes`, `peak_bytes_of`, `roofline_metrics`
  (flops/bytes + per-collective payload totals -- the dry-run launcher's
  metric, lifted here), `parse_collectives` / `shape_bytes`, and the
  HLO-text op census `hlo_op_census`.

* The while-loop trip-count correction `scan_trip_count_totals`: XLA's
  cost_analysis counts each while-loop (lax.scan) body ONCE; given the
  compiled metrics of count-1 / count-2 / accum-2 variants it recovers
  true totals by finite differencing. `launch/dryrun.py` is now a thin
  delegate: it builds the compiled variants, the math is here.

* The per-route resource report over the PR-7 contract registry:
  `resource_report` walks `registry.build_cells()` (the full mode x
  backend x sharded x packed x threshold-side matrix) and emits one row
  {flops, hbm_bytes_read/written, temp_bytes, peak_bytes, jit_entries,
  op_census} per (entry x config) route; `diff_resource_reports` gates a
  fresh report against the committed RESOURCES_baseline.json. CLI:
  `python -m repro.analysis cost` / `cost-diff` -- a perf-regression
  gate with zero timing noise.

This module imports no jax at module scope: `launch/dryrun.py` must be
able to import it before jax initialises its forced device count.
"""

from __future__ import annotations

import json
import re
from typing import Any, Mapping, Sequence

#: dtype token -> bytes per element, for HLO shape strings like f32[8,128].
DTYPE_BYTES: dict[str, int] = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

#: collective op kinds whose payload bytes the roofline metric sums.
COLLECTIVE_KINDS: tuple[str, ...] = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute")

#: cost-bearing opcodes the HLO-text census counts -- most specific
#: first, one match per line, so an "all-gather(" line is a collective
#: and never double-counts as a "gather".
CENSUS_OPS: tuple[str, ...] = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "dynamic-update-slice", "dynamic-slice",
    "gather", "scatter", "dot", "convert", "while", "sort", "iota",
    "transpose", "pad", "fusion", "custom-call")

#: CompiledMemoryStats attributes surfaced by `compiled_memory` (the
#: exact set and order launch/dryrun.py has always reported on stderr).
MEMORY_STATS: tuple[str, ...] = (
    "argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes",
    "generated_code_size_in_bytes")

#: per-operand input terms of cost_analysis ("bytes accessed0{}", ...).
_OPERAND_BYTES_RE = re.compile(r"^bytes accessed\d+\{\}$")
#: the output term ("bytes accessedout{}").
_OUTPUT_BYTES_KEY = "bytes accessedout{}"

#: fields diffed between two resource reports (route-wise).
RESOURCE_FIELDS: tuple[str, ...] = (
    "flops", "hbm_bytes_read", "hbm_bytes_written", "temp_bytes",
    "peak_bytes", "jit_entries")


# -- HLO-text extraction ----------------------------------------------------


def shape_bytes(tok: str) -> int:
    """Bytes of one HLO shape token like ``bf16[16,1024]`` (0 if unknown)."""
    m = _SHAPE_RE.match(tok)
    if not m or m.group(1) not in DTYPE_BYTES:
        return 0
    dims = m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[m.group(1)]


def parse_collectives(hlo: str) -> dict[str, Any]:
    """Sum per-device payload bytes of every collective in partitioned HLO.

    Methodology (documented in EXPERIMENTS.md): result-shape bytes per op,
    doubled for all-reduce (reduce+broadcast phases of a ring); the (P-1)/P
    ring factor is dropped (upper bound).
    """
    out: dict[str, Any] = {k: {"count": 0, "bytes": 0}
                           for k in COLLECTIVE_KINDS}
    for line in hlo.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        for kind in COLLECTIVE_KINDS:
            # match "<kind>(" or "<kind>-start(" as the op on this line
            if re.search(rf"= [^=]*\b{kind}(-start)?\(", s):
                rhs = s.split("=", 1)[1].strip()
                # result type: everything before the op name
                head = re.split(rf"\b{kind}(-start)?\(", rhs)[0]
                shapes = _SHAPE_RE.findall(head)
                nbytes = sum(shape_bytes(f"{t}[{d}]") for t, d in shapes)
                if kind == "all-reduce":
                    nbytes *= 2
                out[kind]["count"] += 1
                out[kind]["bytes"] += nbytes
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def hlo_op_census(hlo: str,
                  ops: Sequence[str] = CENSUS_OPS) -> dict[str, dict[str, int]]:
    """Count cost-bearing ops in HLO text, with result-shape bytes.

    Returns ``{op: {"count": n, "bytes": b}}`` for every op of `ops`
    that appears; `b` sums the result-shape bytes of each matched line
    (the same methodology `parse_collectives` uses, minus the all-reduce
    doubling). One op per line, most specific first.
    """
    out: dict[str, dict[str, int]] = {}
    for line in hlo.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        for op in ops:
            if re.search(rf"= [^=]*\b{op}(-start)?\(", s):
                rhs = s.split("=", 1)[1].strip()
                head = re.split(rf"\b{op}(-start)?\(", rhs)[0]
                nbytes = sum(shape_bytes(f"{t}[{d}]")
                             for t, d in _SHAPE_RE.findall(head))
                rec = out.setdefault(op, {"count": 0, "bytes": 0})
                rec["count"] += 1
                rec["bytes"] += nbytes
                break
    return out


# -- compiled-program extraction --------------------------------------------


def compiled_cost(compiled: Any) -> dict[str, float]:
    """The numeric properties of ``compiled.cost_analysis()`` as one dict
    (first device on jax versions returning one dict per device; empty
    when the backend exposes nothing)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return {str(k): float(v) for k, v in cost.items()
            if isinstance(v, (int, float))}


def hbm_rw_bytes(cost: Mapping[str, float]) -> tuple[float, float]:
    """(read, written) HBM bytes of one compiled program.

    XLA reports the total ``bytes accessed`` plus per-operand
    ``bytes accessed<i>{}`` and output ``bytes accessedout{}`` terms;
    read sums the operand terms (falling back to total - written when a
    backend omits them), written is the output term.
    """
    total = float(cost.get("bytes accessed", 0.0))
    written = float(cost.get(_OUTPUT_BYTES_KEY, 0.0))
    read = sum(v for k, v in cost.items() if _OPERAND_BYTES_RE.match(k))
    if read <= 0.0:
        read = max(total - written, 0.0)
    return read, written


def compiled_memory(compiled: Any) -> dict[str, Any]:
    """``memory_analysis()`` stats as a plain dict in MEMORY_STATS order
    (the exact report launch/dryrun.py prints on stderr);
    ``{"error": ...}`` when the backend exposes no stats."""
    try:
        ma = compiled.memory_analysis()
        return {k: int(getattr(ma, k)) for k in MEMORY_STATS
                if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover - backend-dependent
        return {"error": str(e)}


def temp_bytes(compiled: Any) -> int:
    """Temp-buffer (scratch) bytes of one compiled program (0 when the
    backend exposes no memory stats)."""
    return int(compiled_memory(compiled).get("temp_size_in_bytes", 0))


def peak_bytes_of(mem: Mapping[str, Any]) -> int:
    """Peak-footprint proxy from a `compiled_memory` dict: argument +
    output + temp bytes (XLA exposes no single peak stat; this is the
    live-at-entry working set plus scratch)."""
    return sum(int(mem.get(k, 0)) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes"))


def roofline_metrics(compiled: Any) -> dict[str, float]:
    """Per-device flops/bytes + per-collective byte totals (UNcorrected:
    while-loop bodies counted once -- see scan_trip_count_totals)."""
    cost = compiled_cost(compiled)
    coll = parse_collectives(compiled.as_text())
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0))}
    for k in COLLECTIVE_KINDS:
        out[f"coll_{k}"] = float(coll[k]["bytes"])
    out["coll_total"] = float(coll["total_bytes"])
    return out


# -- while-loop trip-count correction ---------------------------------------


def metric_add(a: Mapping[str, float], b: Mapping[str, float],
               sa: float = 1.0, sb: float = 1.0) -> dict[str, float]:
    """Keywise linear combination ``sa*a + sb*b`` over a's keys."""
    return {k: sa * a[k] + sb * b.get(k, 0.0) for k in a}


def metric_clamp(a: Mapping[str, float]) -> dict[str, float]:
    """Keywise clamp to >= 0 (finite differences can go slightly negative
    when XLA folds a variant differently)."""
    return {k: max(v, 0.0) for k, v in a.items()}


def scan_trip_count_totals(m1: Mapping[str, float],
                           m2_groups: Sequence[Mapping[str, float]],
                           counts: Sequence[int], accum: int,
                           m3: Mapping[str, float] | None = None
                           ) -> dict[str, float]:
    """Trip-count-corrected totals by finite-differencing over scan lengths.

    XLA's cost_analysis counts each while-loop (lax.scan) body ONCE; the
    real step executes layer group g's body L_g times inside an
    accumulation loop of A steps. Given the metrics of the compiled
    variants

        m1         every layer group at count 1, accumulation 1
        m2_groups  group g at count 2 (others 1), accumulation 1
        m3         groups at 1, accumulation 2 (None when A == 1)

    the recovered terms are

        F_g      = M2_g - M1                 (one layer of group g)
        F_micro  = (M3 - M1) - sum_g F_g     (per-microbatch fixed cost)
        F_fixed  = 2*M1 - M3
        total    = F_fixed + A * (F_micro + sum_g L_g * F_g)

    (without m3: F_micro = 0, F_fixed = M1 - sum_g F_g, A = 1). Each
    difference clamps at 0. `counts` holds the true per-group layer
    counts L_g, aligned with m2_groups.
    """
    f_groups = [metric_clamp(metric_add(m2, m1, 1.0, -1.0))
                for m2 in m2_groups]
    sum_fg = {k: sum(f[k] for f in f_groups) for k in m1}
    if m3 is not None:
        f_micro = metric_clamp(metric_add(
            metric_add(m3, m1, 1.0, -1.0), sum_fg, 1.0, -1.0))
        f_fixed = metric_clamp(metric_add(
            m1, metric_add(m3, m1, 1.0, -1.0), 1.0, -1.0))
    else:
        f_micro = {k: 0.0 for k in m1}
        f_fixed = metric_clamp(metric_add(m1, sum_fg, 1.0, -1.0))
        accum = 1
    total: dict[str, float] = {}
    for k in m1:
        inner = f_micro[k] + sum(c * f[k] for c, f in zip(counts, f_groups))
        total[k] = f_fixed[k] + accum * inner
    return total


# -- the per-route resource report ------------------------------------------


def route_key(row: Mapping[str, Any]) -> str:
    """``entry|sorted-config`` -- the same key shape registry.Cell.key
    uses, so resource rows and contract cells align."""
    return f"{row['entry']}|{json.dumps(row['config'], sort_keys=True)}"


def _null_row(entry: str, config: Mapping[str, Any], status: str,
              detail: str) -> dict[str, Any]:
    return {"entry": entry, "config": dict(config), "status": status,
            "detail": detail, "flops": None, "hbm_bytes_read": None,
            "hbm_bytes_written": None, "temp_bytes": None,
            "peak_bytes": None, "jit_entries": None, "op_census": {},
            "while_ops": 0}


def resource_row(entry: str, config: Mapping[str, Any],
                 art: Mapping[str, Any]) -> dict[str, Any]:
    """One resource-report row from a built registry cell's artifacts.

    Cells that compile a program ("compiled" in art) get the full
    {flops, hbm read/written, temp, peak} set with jit_entries = 1;
    the jit-cache cell instead reports its measured entry count
    ("cache_size"); every cell with HLO text gets the op census. Rows
    with a while loop report its presence (`while_ops`) but keep XLA's
    once-per-body counting -- the static baseline must be reproducible
    without the dry-run's variant recompiles (launch/dryrun.py applies
    scan_trip_count_totals where true totals matter).
    """
    row = _null_row(entry, config, "ok", "")
    compiled = art.get("compiled")
    if compiled is not None:
        cost = compiled_cost(compiled)
        read, written = hbm_rw_bytes(cost)
        mem = compiled_memory(compiled)
        row.update(flops=float(cost.get("flops", 0.0)),
                   hbm_bytes_read=read, hbm_bytes_written=written,
                   temp_bytes=int(mem.get("temp_size_in_bytes", 0)),
                   peak_bytes=peak_bytes_of(mem), jit_entries=1)
    hlo = art.get("hlo")
    if hlo is not None:
        census = hlo_op_census(hlo)
        row["op_census"] = census
        row["while_ops"] = census.get("while", {}).get("count", 0)
    if "cache_size" in art:
        row["jit_entries"] = int(art["cache_size"])
    return row


def resource_report(cells: Sequence[Any] | None = None) -> dict[str, Any]:
    """Per-route static resource rows over the contract registry matrix.

    Builds every cell of `registry.build_cells()` (default) and extracts
    its resource row; skipped cells (not enough devices) and build errors
    become rows with a matching status, so the report always has one row
    per registered route.
    """
    import jax

    from repro.analysis import registry

    if cells is None:
        cells = registry.build_cells()
    rows: list[dict[str, Any]] = []
    for cell in cells:
        if cell.skip:
            rows.append(_null_row(cell.entry, cell.config, "skip",
                                  cell.skip))
            continue
        try:
            art = cell.build()
        except Exception as e:          # build error surfaces in the row
            rows.append(_null_row(cell.entry, cell.config, "error",
                                  f"{type(e).__name__}: {e}"))
            continue
        rows.append(resource_row(cell.entry, cell.config, art))
    summary: dict[str, Any] = {"routes": len(rows)}
    for s in ("ok", "skip", "error"):
        summary[s] = sum(1 for r in rows if r["status"] == s)
    summary["total_flops"] = float(sum(r["flops"] or 0.0 for r in rows))
    return {"meta": {"jax": jax.__version__,
                     "jax_backend": jax.default_backend(),
                     "devices": len(jax.devices())},
            "summary": summary, "routes": rows}


def diff_resource_reports(old: Mapping[str, Any], new: Mapping[str, Any],
                          rtol: float = 0.05) -> dict[str, Any]:
    """Route-wise drift between two resource reports.

    Only rows with status "ok" on both sides are compared. A route that
    was ok in `old` but is gone (or no longer ok) in `new` is `missing`
    (red); `jit_entries` must match exactly, every other RESOURCE_FIELD
    within ``rtol`` relative tolerance (absolute floor 1.0, so zero
    baselines do not trip on rounding); new routes are `added`
    (reported, never fatal -- growth is the point).
    """
    old_rows = {route_key(r): r for r in old.get("routes", [])
                if r.get("status") == "ok"}
    new_rows = {route_key(r): r for r in new.get("routes", [])
                if r.get("status") == "ok"}
    missing = sorted(set(old_rows) - set(new_rows))
    added = sorted(set(new_rows) - set(old_rows))
    drifted: list[dict[str, Any]] = []
    for key in sorted(set(old_rows) & set(new_rows)):
        o, n = old_rows[key], new_rows[key]
        for field in RESOURCE_FIELDS:
            ov, nv = o.get(field), n.get(field)
            if ov is None and nv is None:
                continue
            if ov is None or nv is None:
                drifted.append({"route": key, "field": field, "old": ov,
                                "new": nv, "rel": None})
                continue
            ov_f, nv_f = float(ov), float(nv)
            tol = 0.0 if field == "jit_entries" \
                else rtol * max(abs(ov_f), 1.0)
            if abs(nv_f - ov_f) > tol:
                drifted.append({"route": key, "field": field, "old": ov,
                                "new": nv,
                                "rel": (nv_f - ov_f) / max(abs(ov_f), 1.0)})
    return {"drifted": drifted, "missing": missing, "added": added}
