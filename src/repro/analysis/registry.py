"""Declarative contract registry: (invariant x entry-point x config) cells.

Every public entry point of the serving stack is registered here together
with the invariants its compiled program must satisfy, across the full
config matrix:

  engine.search      mode (full/two_phase/ideal) x backend (ref/mxu/fused)
                     x sharded/unsharded x packed/unpacked operand
                     x fused_min_rows (forcing both sides of the dispatch)
                     x routed (nprobe < n_shards on a host-partitioned
                     store engages the phase-0 sketch router, PR 10: the
                     `router_sketch` scope tag must appear iff routing is
                     engaged, and the sketch matmul adds no collectives)
  engine.search_tenants
                     the vmapped multi-tenant dispatch (PR 9) over a
                     ragged 5-tenant stack: same fused/layout/f64
                     invariants as engine.search, plus zero collectives
                     on the tenant axis and the
                     single_jit_entry_across_tenants cache invariant
                     for T in {1, 5, 64}
  MemoryStore.write  scatter path (unsharded / 1-shard) vs shard-local
                     write-through (multi-shard)
  episode_votes      the differentiable training twin of search

`python -m repro.analysis run` lowers each cell via
`jit(...).lower(...).compile()` on small concrete inputs, walks the HLO
text through repro/analysis/hlo_contracts.py (the ONE spelling of each
invariant -- the test suite asserts through the same functions), and
writes results/contract_report.json with pass/fail per cell and the
matched HLO lines on failure.

The fused-tag expectation of every cell is computed from the SAME dispatch
rule the engine uses (repro/engine/sharded._use_fused), so the registry
can never drift from the implementation: a cell fails when compiled
reality and the rule disagree, whichever of the two changed.

Cells needing more devices than available are recorded as skipped with a
reason (the CLI forces an 8-device host platform so nothing skips there).
"""

from __future__ import annotations

import dataclasses
import functools
import json
from typing import Callable

import jax
import jax.numpy as jnp

from repro.analysis import cost as cost_lib
from repro.analysis import hlo_contracts as hc

#: k used by every search cell (small, so cells compile in milliseconds).
CELL_K = 16
#: fused_min_rows values forcing each side of the dispatch rule.
FMR_FORCE_FUSED = 1
FMR_FORCE_DENSE = 1 << 30


@dataclasses.dataclass(frozen=True)
class Cell:
    """One entry-point configuration and the invariants checked on it.

    build() returns the cell's artifacts: at least {"hlo": str}; fused
    cells add "expect_fused", the HBM cells add "hbm", the jit-cache cell
    "cache_size"/"expected".
    """

    entry: str
    config: dict
    invariants: tuple[str, ...]
    build: Callable[[], dict]
    skip: str = ""

    @property
    def key(self) -> str:
        return f"{self.entry}|{json.dumps(self.config, sort_keys=True)}"


# -- invariant name -> checker over cell artifacts --------------------------


def _inv_hbm_buffer_bound(art: dict) -> list[str]:
    h = art["hbm"]
    if h["measured_bytes"] <= h["bound_bytes"]:
        return []
    if not h["strict"]:
        # CPU interpret mode materialises the emulated kernel's blocks, so
        # the O(B*k + N*4d) bound only binds on real TPU HBM; the measured
        # bytes are still recorded in the report for trend tracking.
        return []
    return [f"temp buffers {h['measured_bytes']}B exceed the "
            f"O(B*k + N*4d) bound {h['bound_bytes']}B"]


def _inv_jit_cache(art: dict) -> list[str]:
    if art["cache_size"] == art["expected"]:
        return []
    return [f"{art['cache_size']} jit cache entries for one request "
            f"family (expected {art['expected']}): equal-but-distinct "
            f"SearchRequests or same-shape stores retrace"]


INVARIANTS: dict[str, Callable[[dict], list[str]]] = {
    "no_collectives": lambda a: hc.check_no_collectives(a["hlo"]),
    "no_scatter_any_spelling":
        lambda a: hc.check_no_scatter_any_spelling(a["hlo"]),
    "scatter_write_engaged": lambda a: hc.check_scatter_write(a["hlo"]),
    "no_layout_ops": lambda a: hc.check_no_layout_ops(a["hlo"]),
    "layout_ops_present": lambda a: hc.check_layout_ops_present(a["hlo"]),
    "fused_tag_iff_dispatch_rule":
        lambda a: hc.check_fused_tag(a["hlo"], a["expect_fused"]),
    "router_tag_iff_engaged":
        lambda a: hc.check_router_tag(a["hlo"], a["expect_router"]),
    "no_f64_promotion": lambda a: hc.check_no_f64(a["hlo"]),
    "hbm_buffer_bound": _inv_hbm_buffer_bound,
    "single_jit_cache_entry_per_request_family": _inv_jit_cache,
    "single_jit_entry_across_tenants":
        lambda a: hc.check_single_jit_entry_across_tenants(
            a["cache_sizes"]),
}


# -- shared fixtures (built lazily; tiny shapes, tie-heavy + masked rows) ---


@functools.lru_cache(maxsize=None)
def _fix():
    from repro.core.avss import SearchConfig
    from repro.core.memory import MemoryConfig
    from repro.engine import MemoryStore

    cfg = SearchConfig("mtmc", cl=8, mode="avss", use_kernel="ref")
    base = jax.random.randint(jax.random.PRNGKey(0), (8, 20), 0,
                              cfg.enc.levels)
    sv = jnp.concatenate([base] * 9, axis=0)               # 72 rows, ties
    labels = jnp.where(jnp.arange(72) % 4 == 0, -1,
                       jnp.arange(72)).astype(jnp.int32)   # masked rows
    store = MemoryStore.from_quantized(sv, labels, cfg)
    qv = jax.random.randint(jax.random.PRNGKey(1), (5, 20), 0, 4)

    mcfg = MemoryConfig(capacity=32, dim=16,
                        search=SearchConfig("mtmc", cl=4, mode="avss",
                                            use_kernel="ref"))
    wvecs = jax.random.normal(jax.random.PRNGKey(2), (12, 16))
    wlabs = jnp.arange(12, dtype=jnp.int32)
    wstore = MemoryStore.create(mcfg).calibrate(wvecs)
    return {"cfg": cfg, "store": store, "qv": qv,
            "mcfg": mcfg, "wstore": wstore, "wvecs": wvecs, "wlabs": wlabs}


@functools.lru_cache(maxsize=None)
def _tenant_fix():
    """Ragged 5-tenant stack mirroring the tests/test_tenant.py geometry:
    one empty tenant (calibrated, never written), one tie-heavy tenant,
    masked label -1 rows, plus an interleaved query batch with repeated
    tenants (so the rank-keyed noise coordinates differ from the batch
    positions the solo search would use)."""
    import numpy as np

    from repro.core.avss import SearchConfig
    from repro.core.memory import MemoryConfig
    from repro.engine import MemoryStore, TenantStore

    cfg = SearchConfig("mtmc", cl=8, mode="avss", use_kernel="ref")
    rng = np.random.default_rng(0)
    stores = []
    for i, cap in enumerate((12, 7, 16, 5, 9)):
        if i == 3:                                      # empty tenant
            mc = MemoryConfig(capacity=cap, dim=20, search=cfg)
            sample = jnp.asarray(rng.normal(size=(8, 20)), jnp.float32)
            stores.append(MemoryStore.create(mc).calibrate(sample))
            continue
        v = rng.integers(0, cfg.enc.levels, size=(cap, 20))
        if i == 2:                                      # tie-heavy
            v = np.concatenate([v[:4]] * 4)[:cap]
        lab = rng.integers(0, 5, size=(cap,))
        lab[::4] = -1                                   # masked rows
        stores.append(MemoryStore.from_quantized(
            jnp.asarray(v), jnp.asarray(lab), cfg))
    tstore = TenantStore.stack(stores)
    tids = jnp.array([0, 2, 1, 0, 2, 4, 2, 3, 0], jnp.int32)
    qv = jnp.asarray(rng.integers(0, 4, size=(9, 20)), jnp.int32)
    return {"cfg": cfg, "tstore": tstore, "qv": qv, "tids": tids}


def _compile(fn, *args, mesh=None):
    if mesh is not None:
        with mesh:
            return jax.jit(fn).lower(*args).compile()
    return jax.jit(fn).lower(*args).compile()


def _unpacked(store):
    """The same store streaming the WIDE projection: proj_packed dropped,
    so every fused route takes the unpacked-operand path."""
    return dataclasses.replace(store, proj_packed=None)


def _expect_fused(backend: str, rows_loc: int, mode: str, fmr: int) -> bool:
    """The registry's expectation IS the engine's dispatch rule."""
    from repro.engine.sharded import _use_fused
    if mode == "full":
        return False
    return _use_fused(backend, rows_loc, fmr)


# -- cell builders ----------------------------------------------------------


def _search_cell(mode: str, backend: str, fmr: int, packed: bool,
                 sharded: bool, n_shards: int) -> Cell:
    from repro.engine import RetrievalEngine, SearchRequest

    def build() -> dict:
        fx = _fix()
        store, qv = fx["store"], fx["qv"]
        mesh = None
        if sharded:
            mesh = jax.make_mesh((n_shards,), ("data",))
            store = store.shard(mesh, ("data",))
        if not packed:
            store = _unpacked(store)
        eng = RetrievalEngine(fx["cfg"], backend=backend)
        req = SearchRequest(mode=mode, k=CELL_K, fused_min_rows=fmr)
        compiled = _compile(
            lambda st, q: eng.search(st, q, req).votes, store, qv,
            mesh=mesh)
        rows_loc = store.capacity // (n_shards if sharded else 1)
        art = {"hlo": compiled.as_text(), "compiled": compiled,
               "expect_fused": _expect_fused(backend, rows_loc, mode, fmr)}
        if mode == "ideal" and art["expect_fused"] and not sharded:
            art["hbm"] = _hbm_stats(compiled, qv.shape[0], CELL_K,
                                    store.capacity, store.dim)
        return art

    invariants = ["fused_tag_iff_dispatch_rule", "no_layout_ops",
                  "no_f64_promotion"]
    if not sharded:
        # unsharded searches must not touch collectives at all; sharded
        # two-phase/ideal all-gather the per-shard top-k by design
        invariants.append("no_collectives")
        if (mode == "ideal"
                and _expect_fused(backend, 72, mode, fmr)):
            invariants.append("hbm_buffer_bound")
    skip = ""
    if sharded and len(jax.devices()) < n_shards:
        skip = (f"needs {n_shards} devices, have {len(jax.devices())} "
                f"(run via `python -m repro.analysis run`, which forces "
                f"an 8-device host platform)")
    return Cell(entry="engine.search",
                config={"mode": mode, "backend": backend,
                        "sharded": sharded, "packed": packed,
                        "fused_min_rows": fmr},
                invariants=tuple(invariants), build=build, skip=skip)


def _routed_cell(mode: str, backend: str, fmr: int, packed: bool,
                 nprobe: int, n_shards: int = 8) -> Cell:
    """engine.search with nprobe on a LOGICALLY partitioned store
    (`shard(n_shards=...)`, mesh-less -- so no device minimum and no
    collectives anywhere, sketch matmul included). nprobe < n_shards must
    compile the router (scope tag present); nprobe == n_shards is the
    control: the SAME exhaustive program as nprobe=None, tag absent."""
    from repro.engine import RetrievalEngine, SearchRequest

    engaged = nprobe < n_shards

    def build() -> dict:
        fx = _fix()
        store = fx["store"].shard(n_shards=n_shards)
        if not packed:
            store = _unpacked(store)
        eng = RetrievalEngine(fx["cfg"], backend=backend)
        req = SearchRequest(mode=mode, k=CELL_K, fused_min_rows=fmr,
                            nprobe=nprobe)
        compiled = _compile(
            lambda st, q: eng.search(st, q, req).votes, store, fx["qv"])
        # the routed shortlist ranks the CONCATENATED visited blocks:
        # rows_loc = nprobe * rows_per_shard; the control is exhaustive
        # over the whole (unsharded-dispatch) store
        rows_loc = (nprobe * (store.capacity // n_shards) if engaged
                    else store.capacity)
        return {"hlo": compiled.as_text(), "compiled": compiled,
                "expect_router": engaged,
                "expect_fused": _expect_fused(backend, rows_loc, mode,
                                              fmr)}

    return Cell(entry="engine.search",
                config={"mode": mode, "backend": backend, "packed": packed,
                        "fused_min_rows": fmr, "nprobe": nprobe,
                        "n_shards": n_shards},
                invariants=("router_tag_iff_engaged",
                            "fused_tag_iff_dispatch_rule", "no_layout_ops",
                            "no_f64_promotion", "no_collectives"),
                build=build)


def _hbm_stats(compiled, B: int, k: int, N: int, d: int) -> dict:
    """Temp-buffer bytes of the compiled cell vs the O(B*k + N*4d) bound
    the fused shortlist advertises (kernels/shortlist.py): the per-query
    top-k buffers plus one pass over the streamed projection, times 4 for
    dtype width and double-buffering slack. Strict only on TPU -- the CPU
    interpreter materialises emulated blocks, so there the measured bytes
    are recorded (trend data) without binding."""
    kp = 128 if k <= 128 else k                 # lane-width internal pad
    bound = 4 * 4 * (B * kp * 2 + N * 4 * d)
    measured = cost_lib.temp_bytes(compiled)
    return {"measured_bytes": measured, "bound_bytes": bound,
            "strict": jax.default_backend() == "tpu"}


def _tenant_search_cell(mode: str, backend: str, fmr: int,
                        packed: bool) -> Cell:
    from repro.engine import RetrievalEngine, SearchRequest

    def build() -> dict:
        fx = _tenant_fix()
        tstore, qv, tids = fx["tstore"], fx["qv"], fx["tids"]
        if not packed:
            tstore = _unpacked(tstore)
        eng = RetrievalEngine(fx["cfg"], backend=backend)
        req = SearchRequest(mode=mode, k=CELL_K, fused_min_rows=fmr)
        compiled = _compile(
            lambda ts, q, i: eng.search_tenants(ts, q, i, req).votes,
            tstore, qv, tids)
        # the per-query vmapped search sees every tenant at the PADDED
        # row count -- that is the rows_loc the dispatch rule acts on
        return {"hlo": compiled.as_text(), "compiled": compiled,
                "expect_fused": _expect_fused(backend, tstore.n_pad,
                                              mode, fmr)}

    # the tenant axis is a pure batch axis: beyond the solo-search
    # invariants, the vmapped program must introduce ZERO collectives
    return Cell(entry="engine.search_tenants",
                config={"mode": mode, "backend": backend, "packed": packed,
                        "fused_min_rows": fmr},
                invariants=("fused_tag_iff_dispatch_rule", "no_layout_ops",
                            "no_f64_promotion", "no_collectives"),
                build=build)


def _tenant_jit_cache_cell() -> Cell:
    def build() -> dict:
        from functools import partial

        from repro.engine import (MemoryStore, RetrievalEngine,
                                  SearchRequest, TenantStore)
        fx = _tenant_fix()
        eng = RetrievalEngine(fx["cfg"])

        @partial(jax.jit, static_argnames=("req",))
        def f(ts, q, tids, req):
            return eng.search_tenants(ts, q, tids, req).votes

        req = SearchRequest(mode="two_phase", k=4)

        def mk_stack(T: int, seed: int):
            import numpy as np
            r = np.random.default_rng(seed)
            return TenantStore.stack([
                MemoryStore.from_quantized(
                    jnp.asarray(r.integers(0, fx["cfg"].enc.levels,
                                           size=(6, 8))),
                    jnp.asarray(r.integers(0, 3, size=(6,))), fx["cfg"])
                for _ in range(T)])

        # per tenant count T: fresh stores / queries / tenant_ids of the
        # same shapes must all hit ONE compiled program
        entries: dict[int, int] = {}
        for T in (1, 5, 64):
            before = int(f._cache_size())
            for trial in range(2):
                import numpy as np
                r = np.random.default_rng(100 * T + trial)
                ts = mk_stack(T, seed=T + trial)
                q = jnp.asarray(r.integers(0, 4, size=(4, 8)), jnp.int32)
                tids = jnp.asarray(r.integers(0, T, size=(4,)), jnp.int32)
                f(ts, q, tids, req).block_until_ready()
            entries[T] = int(f._cache_size()) - before
        return {"cache_sizes": entries,
                "cache_size": sum(entries.values()),   # resource row: one
                "expected": len(entries)}              # entry per T shape

    return Cell(entry="engine.search_tenants",
                config={"check": "jit cache across tenant counts"},
                invariants=("single_jit_entry_across_tenants",),
                build=build)


def _write_cell(kind: str, n_shards: int) -> Cell:
    def build() -> dict:
        fx = _fix()
        wstore, vecs, labs = fx["wstore"], fx["wvecs"], fx["wlabs"]
        if kind != "unsharded":
            mesh = jax.make_mesh((n_shards,), ("data",))
            wstore = wstore.shard(mesh, ("data",))
        compiled = _compile(lambda st, v, l: st.write(v, l),
                            wstore, vecs, labs)
        return {"hlo": compiled.as_text(), "compiled": compiled}

    if kind == "multi_shard":
        # the shard-local write-through: programs rows in place with no
        # cross-device traffic and no scatter under any spelling
        invariants = ("no_collectives", "no_scatter_any_spelling",
                      "no_f64_promotion")
    else:
        # unsharded / 1-shard: the scatter fast path must actually engage
        # (7.7x faster there -- see MemoryStore.write), collective-free
        invariants = ("scatter_write_engaged", "no_collectives",
                      "no_f64_promotion")
    skip = ""
    if n_shards > len(jax.devices()):
        skip = (f"needs {n_shards} devices, have {len(jax.devices())} "
                f"(run via `python -m repro.analysis run`)")
    return Cell(entry="MemoryStore.write",
                config={"path": kind, "n_shards": n_shards},
                invariants=invariants, build=build, skip=skip)


def _episode_votes_cell() -> Cell:
    def build() -> dict:
        from repro.engine import RetrievalEngine
        fx = _fix()
        eng = RetrievalEngine(fx["cfg"])
        q = jax.random.normal(jax.random.PRNGKey(3), (4, 20))
        s = jax.random.normal(jax.random.PRNGKey(4), (10, 20))
        compiled = _compile(
            lambda qq, ss: eng.episode_votes(qq, ss)["votes"], q, s)
        return {"hlo": compiled.as_text(), "compiled": compiled}

    return Cell(entry="episode_votes", config={},
                invariants=("no_f64_promotion", "no_collectives"),
                build=build)


def _layout_control_cell() -> Cell:
    def build() -> dict:
        from repro.engine import RetrievalEngine
        fx = _fix()
        eng = RetrievalEngine(fx["cfg"], backend="ref")
        compiled = _compile(
            lambda s, q: eng.two_phase(q, s, k=CELL_K)["votes"],
            fx["store"].values, fx["qv"])
        return {"hlo": compiled.as_text(), "compiled": compiled}

    return Cell(entry="engine.two_phase(raw-arrays)",
                config={"control": "read-time layout"},
                invariants=("layout_ops_present",), build=build)


def _jit_cache_cell() -> Cell:
    def build() -> dict:
        from functools import partial

        from repro.engine import (MemoryStore, RetrievalEngine,
                                  SearchRequest)
        fx = _fix()
        eng = RetrievalEngine(fx["cfg"])

        @partial(jax.jit, static_argnames=("req",))
        def f(store, q, req):
            return eng.search(store, q, req).votes

        store_a = fx["store"]
        store_b = MemoryStore.from_quantized(
            jnp.flip(store_a.values, axis=0), store_a.labels, fx["cfg"])
        # equal-but-distinct request objects + distinct same-shape stores:
        # one request family, and it must hit ONE compiled program
        f(store_a, fx["qv"], SearchRequest(mode="two_phase", k=CELL_K))
        f(store_b, fx["qv"], SearchRequest(mode="two_phase", k=CELL_K))
        return {"cache_size": int(f._cache_size()), "expected": 1}

    return Cell(entry="engine.search", config={"check": "jit cache"},
                invariants=("single_jit_cache_entry_per_request_family",),
                build=build)


def build_cells() -> list[Cell]:
    """The full registered matrix (see module docstring)."""
    n_dev = len(jax.devices())
    n_shards = max(2, min(8, n_dev))            # what the CLI forces to 8
    cells: list[Cell] = []

    # engine.search, unsharded
    for mode in ("full", "two_phase", "ideal"):
        for backend in ("ref", "mxu", "fused"):
            fmrs = ((FMR_FORCE_FUSED,) if mode == "full"
                    or backend == "ref" else (FMR_FORCE_FUSED,
                                              FMR_FORCE_DENSE))
            for fmr in fmrs:
                cells.append(_search_cell(mode, backend, fmr, True,
                                          False, 1))
                if _expect_fused(backend, 72, mode, fmr):
                    # fused cells also cover the unpacked-operand route
                    cells.append(_search_cell(mode, backend, fmr, False,
                                              False, 1))

    # engine.search, sharded (two_phase/ideal dispatch through shard_map)
    for mode in ("two_phase", "ideal"):
        for backend, fmr in (("mxu", FMR_FORCE_FUSED),
                             ("mxu", FMR_FORCE_DENSE),
                             ("fused", FMR_FORCE_DENSE)):
            cells.append(_search_cell(mode, backend, fmr, True, True,
                                      n_shards))
        cells.append(_search_cell(mode, "fused", FMR_FORCE_DENSE, False,
                                  True, n_shards))

    # engine.search, routed (PR 10): nprobe over a host-partitioned store
    # -- both phase-1 dispositions (dense mxu / fused packed + unpacked)
    # plus the nprobe == n_shards control whose program must contain NO
    # router tag (it IS the exhaustive search)
    cells.append(_routed_cell("two_phase", "mxu", FMR_FORCE_DENSE, True, 2))
    cells.append(_routed_cell("two_phase", "fused", FMR_FORCE_FUSED, True,
                              2))
    cells.append(_routed_cell("ideal", "fused", FMR_FORCE_FUSED, True, 2))
    cells.append(_routed_cell("ideal", "fused", FMR_FORCE_FUSED, False, 2))
    cells.append(_routed_cell("two_phase", "mxu", FMR_FORCE_DENSE, True, 8))

    # engine.search_tenants: the vmapped multi-tenant dispatch (PR 9) --
    # one cell per representative route (full dense x ref/mxu, two-phase
    # on both sides of the fused threshold, fused ideal packed + unpacked)
    # plus the cross-tenant-count jit cache cell
    cells.append(_tenant_search_cell("full", "ref", FMR_FORCE_FUSED, True))
    cells.append(_tenant_search_cell("full", "mxu", FMR_FORCE_FUSED, True))
    cells.append(_tenant_search_cell("two_phase", "mxu", FMR_FORCE_DENSE,
                                     True))
    cells.append(_tenant_search_cell("two_phase", "fused", FMR_FORCE_FUSED,
                                     True))
    cells.append(_tenant_search_cell("ideal", "fused", FMR_FORCE_FUSED,
                                     True))
    cells.append(_tenant_search_cell("ideal", "fused", FMR_FORCE_FUSED,
                                     False))
    cells.append(_tenant_jit_cache_cell())

    # MemoryStore.write: scatter vs write-through per n_shards
    cells.append(_write_cell("unsharded", 1))
    cells.append(_write_cell("one_shard", 1))
    cells.append(_write_cell("multi_shard", n_shards))

    cells.append(_episode_votes_cell())
    cells.append(_layout_control_cell())
    cells.append(_jit_cache_cell())
    return cells


# -- runner -----------------------------------------------------------------


def run_cells(cells: list[Cell] | None = None) -> dict:
    """Build + check every cell; returns the contract report dict."""
    if cells is None:
        cells = build_cells()
    rows: list[dict] = []
    for cell in cells:
        if cell.skip:
            for inv in cell.invariants:
                rows.append({"entry": cell.entry, "config": cell.config,
                             "invariant": inv, "status": "skip",
                             "detail": cell.skip, "matched": []})
            continue
        try:
            art = cell.build()
        except Exception as e:                  # build error fails the cell
            for inv in cell.invariants:
                rows.append({"entry": cell.entry, "config": cell.config,
                             "invariant": inv, "status": "error",
                             "detail": f"{type(e).__name__}: {e}",
                             "matched": []})
            continue
        for inv in cell.invariants:
            violations = INVARIANTS[inv](art)
            row = {"entry": cell.entry, "config": cell.config,
                   "invariant": inv,
                   "status": "fail" if violations else "pass",
                   "detail": violations[0] if violations else "",
                   "matched": violations[:8]}
            if inv == "hbm_buffer_bound":
                row["hbm"] = art["hbm"]
            rows.append(row)
    summary = {s: sum(1 for r in rows if r["status"] == s)
               for s in ("pass", "fail", "error", "skip")}
    return {"meta": {"jax_backend": jax.default_backend(),
                     "devices": len(jax.devices())},
            "summary": summary, "cells": rows}
