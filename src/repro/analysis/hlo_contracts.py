"""ONE spelling of every compiled-HLO invariant the repo guarantees.

The architecture's contract is mostly *negative* statements about compiled
programs: a store-based search must not re-run `layout_support`, a sharded
write must not emit collectives or scatter, the fused shortlist kernel must
engage exactly when the dispatch rule says so. Those statements used to be
string asserts scattered through individual tests, each with its own list
of op spellings. This module is now the single home:

* the registry runner (`repro.analysis.registry`) walks every registered
  (invariant x entry-point x config) cell through the `check_*` functions
  and writes results/contract_report.json;
* the test suite calls the thin `assert_*` wrappers over the SAME
  functions, so a new op spelling (say, a new collective) is added in one
  place and every route inherits the check.

Checkers take the compiled HLO text (`jit(...).lower(...).compile()
.as_text()`) and return the list of offending HLO lines -- empty means the
invariant holds. The scope tags they look for are real compiler metadata:
`layout_support` and `shortlist_fused` are `jax.named_scope` tags that
survive into HLO op metadata (see repro/core/avss.py and
repro/kernels/shortlist.py).
"""

from __future__ import annotations

# Cross-device collectives that must never appear in a shard-local write
# (store._program_streamed) or an unsharded search.
COLLECTIVE_OPS = ("all-gather", "all-reduce", "all-to-all",
                  "collective-permute")

# Every spelling XLA uses for a scatter once it reaches HLO: the op itself
# and the dynamic-update-slice it expands to on CPU.
SCATTER_SPELLINGS = ("scatter(", "dynamic-update-slice")

# jax.named_scope tag wrapping the read-time string layout
# (repro/core/avss.layout_support): store-based searches jit against the
# write-time grids and must not contain it.
LAYOUT_SCOPE_TAG = "layout_support"

# jax.named_scope tag wrapping the fused Pallas shortlist
# (repro/kernels/shortlist.lut_shortlist_pallas): present in compiled HLO
# iff the fused kernel was traced.
FUSED_SCOPE_TAG = "shortlist_fused"

# jax.named_scope tag wrapping the phase-0 router's sketch matmul
# (repro/engine/router.route_scores): present in compiled HLO iff a
# search routes through the per-shard summary sketch (nprobe < n_shards).
ROUTER_SCOPE_TAG = "router_sketch"

# Double-precision leak marker: no search/write/training-forward program
# may promote to f64 (jax runs x64-disabled; this guards explicit leaks).
F64_TYPE_TAG = "f64["


def matched_lines(hlo: str, needles) -> list[str]:
    """HLO lines containing any needle (stripped, deduplicated, ordered)."""
    out, seen = [], set()
    for line in hlo.splitlines():
        if any(n in line for n in needles):
            s = line.strip()
            if s not in seen:
                seen.add(s)
                out.append(s)
    return out


# -- checkers: [] == invariant holds ----------------------------------------


def check_no_collectives(hlo: str) -> list[str]:
    """No cross-device collective op appears in the compiled program."""
    return matched_lines(hlo, COLLECTIVE_OPS)


def check_no_scatter_any_spelling(hlo: str) -> list[str]:
    """No scatter under ANY spelling (scatter op or its CPU expansion)."""
    return matched_lines(hlo, SCATTER_SPELLINGS)


def check_scatter_write(hlo: str) -> list[str]:
    """The single-shard / unsharded write DID take the scatter fast path
    (control direction: dynamic-update-slice present)."""
    if matched_lines(hlo, ("dynamic-update-slice",)):
        return []
    return ["expected a dynamic-update-slice (scatter write path) "
            "but the compiled program contains none"]


def check_no_layout_ops(hlo: str) -> list[str]:
    """Store-based searches jit against write-time grids: the read-time
    `layout_support` scope tag must not appear."""
    return matched_lines(hlo, (LAYOUT_SCOPE_TAG,))


def check_layout_ops_present(hlo: str) -> list[str]:
    """Control direction: the raw-array path DOES lay the store out under
    jit, proving the scope tag is visible in this build's HLO text."""
    if matched_lines(hlo, (LAYOUT_SCOPE_TAG,)):
        return []
    return [f"expected the {LAYOUT_SCOPE_TAG!r} scope tag (read-time "
            f"layout) but the compiled program contains none"]


def check_fused_tag(hlo: str, expected: bool) -> list[str]:
    """The `shortlist_fused` scope tag appears iff the dispatch rule
    (repro/engine/sharded._use_fused) says the fused kernel engages."""
    lines = matched_lines(hlo, (FUSED_SCOPE_TAG,))
    if expected and not lines:
        return [f"dispatch rule says the fused shortlist engages but the "
                f"{FUSED_SCOPE_TAG!r} tag is absent from the compiled HLO"]
    if not expected and lines:
        return lines
    return []


def check_router_tag(hlo: str, expected: bool) -> list[str]:
    """The `router_sketch` scope tag appears iff routing is engaged
    (`SearchRequest.nprobe < store.n_shards`) -- exhaustive searches must
    not pay the sketch matmul, routed ones must go through it."""
    lines = matched_lines(hlo, (ROUTER_SCOPE_TAG,))
    if expected and not lines:
        return [f"nprobe < n_shards engages the router but the "
                f"{ROUTER_SCOPE_TAG!r} tag is absent from the compiled HLO"]
    if not expected and lines:
        return lines
    return []


def check_no_f64(hlo: str) -> list[str]:
    """No f64 tensor anywhere in the compiled program."""
    return matched_lines(hlo, (F64_TYPE_TAG,))


def check_single_jit_entry_across_tenants(entries) -> list[str]:
    """ONE compiled search program serves any tenant count (PR 9).

    `entries` maps tenant count T -> jit cache entries added by repeated
    `search_tenants` calls at that T (fresh stores / queries / tenant_ids
    each call, same shapes). The multi-tenant contract is exactly one
    entry per T: a second entry at any T means something per-tenant or
    per-write leaked into the trace (e.g. a python-level branch on tenant
    data) and every tenant would pay its own compile again."""
    return [f"tenant count {t}: {n} jit cache entries added "
            f"(expected exactly 1)"
            for t, n in sorted(entries.items()) if n != 1]


# -- assert wrappers (the test-suite surface) -------------------------------


def _raise(violations: list[str], what: str) -> None:
    if violations:
        shown = "\n  ".join(violations[:8])
        raise AssertionError(f"{what}:\n  {shown}")


def assert_no_collectives(hlo: str) -> None:
    _raise(check_no_collectives(hlo), "collective ops in compiled HLO")


def assert_no_scatter_any_spelling(hlo: str) -> None:
    _raise(check_no_scatter_any_spelling(hlo),
           "scatter (any spelling) in compiled HLO")


def assert_scatter_write(hlo: str) -> None:
    _raise(check_scatter_write(hlo), "scatter write path did not engage")


def assert_no_layout_ops(hlo: str) -> None:
    _raise(check_no_layout_ops(hlo),
           "read-time layout_support ops in a store-based search")


def assert_layout_ops_present(hlo: str) -> None:
    _raise(check_layout_ops_present(hlo), "layout scope tag not visible")


def assert_fused_tag(hlo: str, expected: bool) -> None:
    _raise(check_fused_tag(hlo, expected),
           f"fused-shortlist tag mismatch (expected engaged={expected})")


def assert_router_tag(hlo: str, expected: bool) -> None:
    _raise(check_router_tag(hlo, expected),
           f"router-sketch tag mismatch (expected engaged={expected})")


def assert_no_f64(hlo: str) -> None:
    _raise(check_no_f64(hlo), "f64 promotion in compiled HLO")


def assert_single_jit_entry_across_tenants(entries) -> None:
    _raise(check_single_jit_entry_across_tenants(entries),
           "multi-tenant search retraced per tenant count")
