"""Repo-specific AST lint: rules generic linters cannot know.

Run as `python -m repro.analysis lint [paths...]` (default: src/repro).
Each finding carries a rule id; suppress a specific line with an
annotation comment naming the rule, trailing or on the line above:

    neg, pos = jax.lax.top_k(-dist, kp)   # lint: allow=kernel-sort

Rules (ids in brackets):

  [deprecated-shim]       src/ must not call the deprecated
                          `repro.core.memory.search/distributed_search`
                          shims internally -- everything goes through
                          `RetrievalEngine.search` (the shims exist only
                          for external callers and emit
                          DeprecationWarning).
  [kernel-sort]           no `lax.sort` / `lax.top_k` inside a function
                          passed to `pallas_call`: Mosaic lowers neither,
                          so such code only works in interpret mode.
                          Interpret-only branches must be annotated.
  [float-epsilon-tiebreak] no small float epsilons (0 < |x| < 1e-4) in
                          ranking code (repro/engine, repro/kernels): ties
                          break by (distance, index) lexicographic order,
                          never by epsilon nudges (an epsilon below the
                          f32 ulp of a vote silently does nothing -- a
                          seed bug PR 1 fixed).
  [serving-raw-random]    no `jax.random` sampler calls in serving paths
                          (repro/engine, repro/kernels): serving noise is
                          the counter-hash family keyed on absolute
                          coordinates (core/mcam.hash_normal), which is
                          what makes results independent of shard/tile
                          assignment. `jax.random.key_data` (key
                          introspection, not sampling) is allowed.
  [ste-raw-primitive]     the STE fwd/bwd primitives (`_ste_round_fwd`,
                          `_mtmc_ste_bwd`, ...) are only touched inside
                          their defining modules -- everyone else uses the
                          custom_vjp wrappers (`ste_round`,
                          `encode_words_ste`, `ste_step`).
  [f64-astype]            no `.astype(jnp.float64)` / `astype("float64")`
                          -- the stack is f32/bf16/int; host-side
                          `np.float64` (LUT construction) is fine.
  [cost-call]             no direct `compiled.cost_analysis()` /
                          `compiled.memory_analysis()` calls outside
                          `repro.analysis` -- the resource oracle
                          (repro/analysis/cost.py) is the ONE cost
                          model; readers go through its helpers so
                          per-device list handling, stat-name drift and
                          error fallbacks stay in one place.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re

#: modules allowed to touch the raw STE fwd/bwd primitives (they define
#: them); everyone else goes through the custom_vjp wrappers.
STE_DEFINING_MODULES = ("core/quantization.py", "core/encodings.py",
                        "core/mcam.py")
#: ranking / serving path prefixes for the epsilon + raw-random rules.
SERVING_PREFIXES = ("repro/engine/", "repro/kernels/")
_STE_PRIMITIVE = re.compile(r"^_\w*ste\w*_(fwd|bwd)$")
_ALLOW = re.compile(r"#\s*lint:\s*allow=([\w,-]+)")
EPSILON_BOUND = 1e-4


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _suppressed(source_lines: list[str], line: int, rule: str) -> bool:
    for ln in (line, line - 1):                # trailing or line-above
        if 1 <= ln <= len(source_lines):
            m = _ALLOW.search(source_lines[ln - 1])
            if m and rule in m.group(1).split(","):
                return True
    return False


def _dotted(node: ast.AST) -> str:
    """'a.b.c' for nested Attribute/Name nodes ('' when not a plain path)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


# -- rules (each: (tree, path, source_lines) -> list[Finding]) --------------


def _rule_deprecated_shim(tree, path, lines):
    if path.endswith("core/memory.py"):        # the shims' own home
        return []
    out = []
    shims = {"search", "distributed_search"}
    memory_aliases = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            if node.module == "repro.core.memory":
                for a in node.names:
                    if a.name in shims:
                        out.append(Finding(
                            "deprecated-shim", path, node.lineno,
                            f"import of deprecated shim "
                            f"repro.core.memory.{a.name}; use "
                            f"RetrievalEngine.search"))
            elif node.module == "repro.core":
                for a in node.names:
                    if a.name == "memory":
                        memory_aliases.add(a.asname or a.name)
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro.core.memory" and a.asname:
                    memory_aliases.add(a.asname)
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in shims
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id in memory_aliases):
            out.append(Finding(
                "deprecated-shim", path, node.lineno,
                f"call to deprecated shim "
                f"{node.func.value.id}.{node.func.attr}(); use "
                f"RetrievalEngine.search"))
    return out


def _kernel_functions(tree) -> dict[str, ast.AST]:
    """Names of functions handed to pallas_call (directly, via a variable,
    or wrapped in functools.partial) -> their FunctionDef nodes."""
    defs = {n.name: n for n in ast.walk(tree)
            if isinstance(n, ast.FunctionDef)}
    partial_of = {}                            # var name -> wrapped fn name
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)
                and _dotted(node.value.func).endswith("partial")
                and node.value.args
                and isinstance(node.value.args[0], ast.Name)):
            partial_of[node.targets[0].id] = node.value.args[0].id
    kernels = {}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _dotted(node.func).endswith("pallas_call")
                and node.args):
            continue
        arg = node.args[0]
        name = None
        if isinstance(arg, ast.Name):
            name = partial_of.get(arg.id, arg.id)
        elif (isinstance(arg, ast.Call)
              and _dotted(arg.func).endswith("partial") and arg.args
              and isinstance(arg.args[0], ast.Name)):
            name = arg.args[0].id
        if name in defs:
            kernels[name] = defs[name]
    return kernels


def _rule_kernel_sort(tree, path, lines):
    out = []
    for name, fn in _kernel_functions(tree).items():
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                d = _dotted(node.func)
                if d.endswith("lax.sort") or d.endswith("lax.top_k"):
                    out.append(Finding(
                        "kernel-sort", path, node.lineno,
                        f"{d} inside pallas kernel {name}(): Mosaic "
                        f"lowers neither -- interpret-only paths must be "
                        f"annotated `# lint: allow=kernel-sort`"))
    return out


def _rule_float_epsilon(tree, path, lines):
    if not any(p in path for p in SERVING_PREFIXES):
        return []
    out = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Constant)
                and isinstance(node.value, float)
                and 0.0 < abs(node.value) < EPSILON_BOUND):
            out.append(Finding(
                "float-epsilon-tiebreak", path, node.lineno,
                f"float epsilon {node.value!r} in ranking code: ties "
                f"break by (distance, index) order, not epsilon nudges"))
    return out


def _rule_serving_raw_random(tree, path, lines):
    if not any(p in path for p in SERVING_PREFIXES):
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute):
            d = _dotted(node)
            if (d.startswith("jax.random.")
                    and d != "jax.random.key_data"):
                out.append(Finding(
                    "serving-raw-random", path, node.lineno,
                    f"{d} in a serving path: serving noise is the "
                    f"counter-hash family (core/mcam.hash_normal), not "
                    f"jax.random sampling"))
    return out


def _rule_ste_raw_primitive(tree, path, lines):
    if any(path.endswith(m) for m in STE_DEFINING_MODULES):
        return []
    out = []
    for node in ast.walk(tree):
        name = None
        if isinstance(node, ast.Name):
            name = node.id
        elif isinstance(node, ast.Attribute):
            name = node.attr
        elif isinstance(node, ast.ImportFrom):
            for a in node.names:
                if _STE_PRIMITIVE.match(a.name):
                    out.append(Finding(
                        "ste-raw-primitive", path, node.lineno,
                        f"import of raw STE primitive {a.name}; use the "
                        f"custom_vjp wrapper"))
            continue
        if name and _STE_PRIMITIVE.match(name):
            out.append(Finding(
                "ste-raw-primitive", path, node.lineno,
                f"use of raw STE primitive {name}; use the custom_vjp "
                f"wrapper (ste_round / encode_words_ste / ste_step)"))
    return out


def _rule_f64_astype(tree, path, lines):
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and _dotted(node).endswith(
                "jnp.float64"):
            out.append(Finding(
                "f64-astype", path, node.lineno,
                "jnp.float64 in device code: the stack is f32/bf16/int "
                "(host-side np.float64 is fine)"))
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Attribute)
              and node.func.attr == "astype" and node.args
              and isinstance(node.args[0], ast.Constant)
              and node.args[0].value == "float64"):
            out.append(Finding(
                "f64-astype", path, node.lineno,
                'astype("float64") in device code'))
    return out


def _rule_cost_call(tree, path, lines):
    if "repro/analysis" in path.replace(os.sep, "/"):
        return []                       # the cost model's own home
    out = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("cost_analysis",
                                       "memory_analysis")):
            out.append(Finding(
                "cost-call", path, node.lineno,
                f"direct {node.func.attr}() call outside repro.analysis; "
                f"go through repro.analysis.cost (the one cost model)"))
    return out


RULES = {
    "deprecated-shim": _rule_deprecated_shim,
    "kernel-sort": _rule_kernel_sort,
    "float-epsilon-tiebreak": _rule_float_epsilon,
    "serving-raw-random": _rule_serving_raw_random,
    "ste-raw-primitive": _rule_ste_raw_primitive,
    "f64-astype": _rule_f64_astype,
    "cost-call": _rule_cost_call,
}


def lint_source(source: str, path: str) -> list[Finding]:
    """All findings for one file's source text (suppressions applied)."""
    tree = ast.parse(source, filename=path)
    lines = source.splitlines()
    out = []
    for rule_id, rule in RULES.items():
        for f in rule(tree, path, lines):
            if not _suppressed(lines, f.line, f.rule):
                out.append(f)
    return sorted(out, key=lambda f: (f.path, f.line, f.rule))


def lint_paths(paths: list[str]) -> list[Finding]:
    """Lint every .py file under the given files/directories."""
    files: list[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                files.extend(os.path.join(root, n) for n in names
                             if n.endswith(".py"))
        else:
            files.append(p)
    out = []
    for fp in sorted(files):
        with open(fp, encoding="utf-8") as fh:
            out.extend(lint_source(fh.read(), fp))
    return out
