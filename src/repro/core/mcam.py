"""Behavioural model of the 3D-NAND multi-bit CAM (MCAM) of Tseng et al. [14].

The MCAM stores vectors on NAND strings of ``string_len`` (default 24) unit
cells; a search applies the query on shared word lines and the per-string
current encodes similarity. Physics captured here (paper Fig. 2):

* Each unit cell produces a mismatch level m in {0, 1, 2, 3} between the
  searched word and the stored word.
* The string is a SERIES connection, so we model each cell as a resistance
  growing exponentially with its mismatch level, R(m) = rho**m, and the
  string current as I = string_len / sum_c rho**(m_c). This reproduces both
  measured behaviours in Fig. 2(b)/(c):
    - current decreases monotonically with the summed mismatch, and
    - for a fixed summed mismatch, a single high-mismatch cell dominates
      (the "bottleneck effect": mismatch-3 strings sink far below
      mismatch-1 strings of equal total mismatch).
* Device variation perturbs the effective mismatch exponent with Gaussian
  noise (sigma_device), and the sense path adds multiplicative read noise
  (sigma_read) -- the Gaussian noise model the paper adopts from CAMASim [15].
* A sense amplifier compares the string current against ``n_thresholds``
  reference levels; the per-string vote is the count of thresholds exceeded.

Noise is generated with a counter-based hash (deterministic given a seed and
the absolute (query, string, cell) coordinates) so that the Pallas kernels and
the pure-jnp reference produce bit-identical results, and searches are
reproducible across shardings.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encodings import MAX_MISMATCH

DEFAULT_STRING_LEN = 24


@dataclasses.dataclass(frozen=True)
class MCAMConfig:
    """Hardware parameters of the simulated MCAM block."""

    string_len: int = DEFAULT_STRING_LEN
    rho: float = 8.0            # per-mismatch-level series resistance ratio
    sigma_device: float = 0.12  # stddev of per-cell mismatch-exponent noise
    sigma_read: float = 0.04    # stddev of multiplicative current read noise
    n_thresholds: int = 8       # SA reference levels
    max_strings: int = 131072   # 128K strings per block [14]
    seed: int = 0

    def thresholds(self) -> np.ndarray:
        """SA reference currents. Calibrated to ideal currents of strings with
        s uniformly-spread single-level mismatches, s geometrically spaced --
        dense resolution near perfect matches where decisions happen."""
        smax = 1.5 * self.string_len
        s = np.unique(np.round(np.geomspace(1.0, smax, self.n_thresholds)))
        while len(s) < self.n_thresholds:  # pad with linear extras
            extra = s[-1:] + np.arange(1, 1 + self.n_thresholds - len(s))
            s = np.unique(np.concatenate([s, extra]))
        s = s[: self.n_thresholds].astype(np.float64)
        i_ideal = self.string_len / ((self.string_len - s) + s * self.rho)
        return np.sort(i_ideal).astype(np.float32)  # ascending


# ---------------------------------------------------------------------------
# Counter-based deterministic noise (shared by kernels and reference).
# ---------------------------------------------------------------------------

_M1 = np.uint32(0x7FEB352D)
_M2 = np.uint32(0x846CA68B)


def _mix(x: jax.Array) -> jax.Array:
    """murmur3-style 32-bit finalizer (vectorised, uint32 in/out)."""
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 15)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def hash_uniform(*idx: jax.Array, seed: int) -> jax.Array:
    """Deterministic uniform(0,1) from integer coordinates (broadcasting)."""
    h = jnp.uint32(seed) * jnp.uint32(0x9E3779B9) + jnp.uint32(0x85EBCA6B)
    for k, i in enumerate(idx):
        step = jnp.uint32(k + 1) * jnp.uint32(0x9E3779B9)
        h = _mix(h ^ (jnp.asarray(i).astype(jnp.uint32) + step))
    return (h.astype(jnp.float32) + 0.5) * jnp.float32(1.0 / 4294967296.0)


def hash_normal(*idx: jax.Array, seed: int) -> jax.Array:
    """Deterministic standard normal via Box-Muller over two hash streams."""
    u1 = hash_uniform(*idx, seed=seed)
    u2 = hash_uniform(*idx, seed=seed + 0x5BD1)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(2.0 * jnp.float32(np.pi) * u2)


# ---------------------------------------------------------------------------
# String current + sense amplifier.
# ---------------------------------------------------------------------------


def string_resistance(cell_mismatch: jax.Array, cfg: MCAMConfig,
                      device_noise: jax.Array | None = None) -> jax.Array:
    """Sum of per-cell series resistances; reduces the trailing axis.

    cell_mismatch: (..., cells) float or int mismatch levels in [0, 3].
    device_noise:  optional (..., cells) standard-normal perturbations.
    """
    m = cell_mismatch.astype(jnp.float32)
    if device_noise is not None:
        m = m + cfg.sigma_device * device_noise
        m = jnp.clip(m, 0.0, float(MAX_MISMATCH))
    return jnp.power(jnp.float32(cfg.rho), m).sum(-1)


def current_from_resistance(r_sum: jax.Array, n_cells: int, cfg: MCAMConfig,
                            read_noise: jax.Array | None = None) -> jax.Array:
    """I = n_cells / sum_R, normalised so a perfect match reads 1.0."""
    i = jnp.float32(n_cells) / r_sum
    if read_noise is not None:
        i = i * (1.0 + cfg.sigma_read * read_noise)
    return i


def string_current(cell_mismatch: jax.Array, cfg: MCAMConfig, *,
                   noise_idx: tuple[jax.Array, ...] | None = None) -> jax.Array:
    """Full noisy current for strings of cells; reduces the trailing axis.

    noise_idx: integer coordinate arrays broadcastable to
      cell_mismatch.shape[:-1]; when given, deterministic device/read noise is
      derived from them (plus the cell index for device noise).
    """
    n_cells = cell_mismatch.shape[-1]
    if noise_idx is None:
        r = string_resistance(cell_mismatch, cfg)
        return current_from_resistance(r, n_cells, cfg)
    cell = jnp.arange(n_cells, dtype=jnp.uint32)
    bidx = tuple(jnp.asarray(i)[..., None] for i in noise_idx)
    dn = hash_normal(*bidx, cell, seed=cfg.seed)
    rn = hash_normal(*noise_idx, seed=cfg.seed + 0x2C1B)
    r = string_resistance(cell_mismatch, cfg, device_noise=dn)
    return current_from_resistance(r, n_cells, cfg, read_noise=rn)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def ste_step(x: jax.Array, tau: float) -> jax.Array:
    """Sense-amp comparator STE: hard step forward, sigmoid gradient
    backward (paper Fig. 8(c)). The forward is EXACTLY the comparison the
    serving `sa_votes` makes -- (x > 0) == (current > threshold) -- so
    training through it and serving without it agree bit-for-bit."""
    return (x > 0).astype(jnp.float32)


def _ste_step_fwd(x, tau):
    return (x > 0).astype(jnp.float32), x


def _ste_step_bwd(tau, x, g):
    s = jax.nn.sigmoid(x / tau)
    return (g * s * (1 - s) / tau,)


ste_step.defvjp(_ste_step_fwd, _ste_step_bwd)


def sa_votes(currents: jax.Array, cfg: MCAMConfig,
             thresholds: jax.Array | None = None, *,
             step_fn=None) -> jax.Array:
    """Sense-amplifier voting: count of reference levels the current exceeds.

    step_fn: optional differentiable step (e.g. `partial(ste_step, tau=...)`
    via a lambda) used by hardware-aware training; its forward must equal
    the hard comparison, which `ste_step` guarantees -- the vote VALUES are
    identical either way, only gradients differ."""
    th = jnp.asarray(cfg.thresholds() if thresholds is None else thresholds)
    if step_fn is None:
        return (currents[..., None] > th).sum(-1).astype(jnp.float32)
    return step_fn(currents[..., None] - th).sum(-1)


def ideal_current(total_mismatch: jax.Array, cfg: MCAMConfig) -> jax.Array:
    """Noise-free current of a string whose mismatch is spread one level per
    cell (the best case for a given total) -- used for SA calibration."""
    s = total_mismatch.astype(jnp.float32)
    n = jnp.float32(cfg.string_len)
    return n / ((n - s) + s * cfg.rho)
