"""Encoding schemes for MCAM vector similarity search.

Implements the paper's proposed Multi-bit Thermometer Code (MTMC) plus every
baseline it compares against:

  * MTMC  -- 4-ary thermometer code (paper Sec. 3.1, Table 1).
  * B4E   -- base-4 bit slicing (Hsu et al. [18]).
  * B4WE  -- base-4 weighted encoding: B4E with word i repeated 4^(i-1) times,
             MSB repeated most (Kim et al. [19]).
  * SRE   -- simple repetition encoding: the 4-level value repeated r times
             (Li et al. [11], SAPIENS).

Every code word is an integer in [0, 3] (one MLC unit cell = 4 states).
An ``Encoding`` bundles the mapping value -> code words, the per-word
accumulation weights (Eq. 2 of the paper), and the number of representable
quantization levels.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

CELL_STATES = 4  # MLC flash: 4 programmable states per unit cell.
MAX_MISMATCH = CELL_STATES - 1


@dataclasses.dataclass(frozen=True)
class Encoding:
    """A value -> code-word mapping for MCAM storage.

    Attributes:
      name: scheme identifier.
      cl: the scheme's code-word-length parameter (see paper Table 1).
      length: total number of unit cells per dimension after encoding
        (== cl for MTMC/B4E, r for SRE, (4^cl-1)/3 for B4WE).
      levels: number of representable quantization levels.
      weights: (length,) per-word accumulation weight s_i of Eq. (2).
    """

    name: str
    cl: int
    length: int
    levels: int
    weights: tuple

    def encode(self, values: jax.Array) -> jax.Array:
        """(...,) ints in [0, levels) -> (..., length) code words in [0, 3]."""
        return _ENCODERS[self.name](values, self.cl)

    def decode(self, codes: jax.Array) -> jax.Array:
        """(..., length) code words -> (...,) values. Inverse of encode."""
        w = jnp.asarray(self.weights, dtype=codes.dtype)
        if self.name == "mtmc":
            return codes.sum(-1)
        if self.name == "sre":
            # All words equal; integer-average to be robust to perturbation.
            return jnp.round(codes.mean(-1)).astype(codes.dtype)
        # b4e / b4we: weighted positional sum; b4we repeats need de-duplication
        # by dividing each repeated group's weight by its repeat count --
        # folded into `weights` already being per-word positional values.
        if self.name == "b4e":
            return (codes * w).sum(-1)
        # b4we: each significance j appears 4^j times with weight 4^j each;
        # recover digit as mean of its group then positional-sum.
        vals = jnp.zeros(codes.shape[:-1], dtype=codes.dtype)
        idx = 0
        for j in reversed(range(self.cl)):  # MSB first in storage order
            rep = CELL_STATES**j
            digit = jnp.round(codes[..., idx : idx + rep].mean(-1))
            vals = vals + digit.astype(codes.dtype) * (CELL_STATES**j)
            idx += rep
        return vals

    def weights_array(self, dtype=jnp.float32) -> jax.Array:
        return jnp.asarray(self.weights, dtype=dtype)


def _mtmc_encode(values: jax.Array, cl: int) -> jax.Array:
    """Multi-bit thermometer code (paper Sec. 3.1).

    value m -> first cl-n words = x, last n words = x+1 with
    x = m // cl, n = m mod cl. Range [0, 3*cl].
    """
    values = jnp.asarray(values)
    x = values // cl
    n = values % cl
    w = jnp.arange(cl, dtype=values.dtype)
    codes = x[..., None] + (w >= (cl - n)[..., None]).astype(values.dtype)
    return jnp.clip(codes, 0, MAX_MISMATCH)


def _b4e_encode(values: jax.Array, cl: int) -> jax.Array:
    """Base-4 encoding, MSB first (value 7, cl=2 -> [1, 3])."""
    values = jnp.asarray(values)
    shifts = np.array([CELL_STATES ** (cl - 1 - i) for i in range(cl)])
    shifts = jnp.asarray(shifts, dtype=values.dtype)
    return (values[..., None] // shifts) % CELL_STATES


def _sre_encode(values: jax.Array, r: int) -> jax.Array:
    """Simple repetition: 4-level value repeated r times."""
    values = jnp.asarray(values)
    return jnp.repeat(values[..., None], r, axis=-1)


def _b4we_encode(values: jax.Array, cl: int) -> jax.Array:
    """Base-4 weighted encoding: B4E word of significance j repeated 4^j
    times (MSB repeated most), realising Eq. (2) weights by duplication."""
    b4e = _b4e_encode(values, cl)  # MSB first
    parts = []
    for i in range(cl):  # storage order: MSB group first
        j = cl - 1 - i  # significance
        parts.append(jnp.repeat(b4e[..., i : i + 1], CELL_STATES**j, axis=-1))
    return jnp.concatenate(parts, axis=-1)


_ENCODERS = {
    "mtmc": _mtmc_encode,
    "b4e": _b4e_encode,
    "sre": _sre_encode,
    "b4we": _b4we_encode,
}


# ---------------------------------------------------------------------------
# Differentiable (straight-through) encoders for hardware-aware training.
#
# The forward values are EXACTLY the hard encoders above (bit-for-bit on
# integer-valued inputs); only the backward pass differs. Keeping the STEs
# here, next to the encoders they wrap, is what guarantees training and
# serving can never drift: `encode_words_ste(v, enc)` forward ==
# `enc.encode(v)`, the function the engine traces at write time.
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def mtmc_word_ste(v: jax.Array, c: int, cl: int) -> jax.Array:
    """c-th MTMC code word of (integer-valued) v; backward slope 1/CL
    (paper Fig. 8(b): the discrete encoder's trend line). Forward equals
    column c of `_mtmc_encode` exactly."""
    x = jnp.floor(v / cl)
    n = v - x * cl
    return jnp.clip(x + (c >= cl - n), 0, MAX_MISMATCH)


def _mtmc_ste_fwd(v, c, cl):
    return mtmc_word_ste(v, c, cl), None


def _mtmc_ste_bwd(c, cl, _, g):
    return (g / cl,)


mtmc_word_ste.defvjp(_mtmc_ste_fwd, _mtmc_ste_bwd)


def encode_words_ste(v: jax.Array, enc: Encoding) -> jax.Array:
    """(...,) integer-valued float values -> (..., length) code words with
    straight-through gradients. Forward is bit-identical to `enc.encode`
    (the write-time encoder); backward follows the encoder's trend line
    (slope 1/CL for MTMC, unit slope spread over the words otherwise)."""
    if enc.name == "mtmc":
        words = [mtmc_word_ste(v, c, enc.cl) for c in range(enc.cl)]
        return jnp.stack(words, axis=-1)
    hard = enc.encode(v.astype(jnp.int32)).astype(jnp.float32)
    # identity-STE fallback: hard forward (the +0 term is exactly zero),
    # gradient 1/length to each word
    return hard + (v[..., None] - jax.lax.stop_gradient(v[..., None])) \
        / enc.length


def make_encoding(name: str, cl: int) -> Encoding:
    """Factory. `cl` is the code-word-length parameter from the paper:
    word count for mtmc/b4e, repeat count for sre, base word count for b4we.
    """
    name = name.lower()
    if name == "mtmc":
        return Encoding(name, cl, cl, 3 * cl + 1, tuple([1.0] * cl))
    if name == "b4e":
        w = tuple(float(CELL_STATES ** (cl - 1 - i)) for i in range(cl))
        return Encoding(name, cl, cl, CELL_STATES**cl, w)
    if name == "sre":
        return Encoding(name, cl, cl, CELL_STATES, tuple([1.0] * cl))
    if name == "b4we":
        length = (CELL_STATES**cl - 1) // 3
        w = []
        for i in range(cl):
            j = cl - 1 - i
            w.extend([1.0] * (CELL_STATES**j))
        return Encoding(name, cl, length, CELL_STATES**cl, tuple(w))
    raise ValueError(f"unknown encoding {name!r}")


# ---------------------------------------------------------------------------
# AVSS lookup tables.
#
# Under AVSS the query is quantized to 4 levels (one code word per dimension)
# and compared against ALL code words of the support in that dimension. For a
# support value v encoded as words code_c(v) with weights w_c, the
# per-dimension contributions are a pure function of (q, v):
#
#   LUT_sum[q, v] = sum_c w_c * |q - code_c(v)|       (accumulated similarity)
#   LUT_wrd[c][q, v] = |q - code_c(v)|                (per-string mismatch)
#
# For MTMC this collapses to the exact identity LUT_sum[q, v] = |cl*q - v|
# (proved in tests), which is what makes the MXU formulation possible.
# ---------------------------------------------------------------------------


def avss_word_luts(enc: Encoding) -> np.ndarray:
    """(length, 4, levels) int table: |q - code_c(v)| per word c.

    Evaluated eagerly even under an active jit trace (the table is a
    compile-time constant of the encoding, not data)."""
    with jax.ensure_compile_time_eval():
        v = np.arange(enc.levels)
        codes = np.asarray(jax.device_get(enc.encode(jnp.asarray(v))))
    q = np.arange(CELL_STATES)[:, None]  # (4, 1)
    # (length, 4, levels)
    return np.abs(q[None] - codes.T[:, None, :]).astype(np.int32)


def avss_sum_lut(enc: Encoding) -> np.ndarray:
    """(4, levels) float: weighted summed mismatch per (query word, value)."""
    luts = avss_word_luts(enc).astype(np.float64)  # (L, 4, levels)
    w = np.asarray(enc.weights, dtype=np.float64)[:, None, None]
    return (luts * w).sum(0).astype(np.float32)


def avss_max_lut(enc: Encoding) -> np.ndarray:
    """(4, levels) int: max per-word mismatch per (query word, value)."""
    return avss_word_luts(enc).max(0).astype(np.int32)


def svss_pair_mismatch(enc: Encoding, a: jax.Array, b: jax.Array) -> jax.Array:
    """Per-word |code(a) - code(b)| for symmetric search. (..., length)."""
    return jnp.abs(enc.encode(a) - enc.encode(b))
