"""Symmetric (SVSS) and Asymmetric (AVSS) vector similarity search on MCAM.

Storage layout (paper Fig. 4, generalised): a support vector with d dimensions
encoded into L code words per dimension occupies a grid of NAND strings

    (n_seg, L) strings,   n_seg = ceil(d / string_len)

where string (seg, c) holds the c-th code word of the ``string_len`` dimensions
in segment ``seg``. Code-word significance is therefore uniform within a
string, realising Eq. (2)'s weighted accumulation with one weight per string.

* SVSS: the query is encoded identically, and every string requires its own
  word-line cycle  ->  iterations = L * n_seg.
* AVSS: the query keeps ONE 4-level word per dimension; the same word-line
  setting is shared by all L strings of a segment, which are sensed in
  parallel  ->  iterations = n_seg.  (32x fewer for Omniglot's CL=32,
  25x for CUB's CL=25 -- paper Table 2.)

The search result per (query, support) is the accumulated, weighted SA vote
count over all strings; prediction is 1-NN on votes (vote ties broken by the
ideal digital distance) or per-class vote sums.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import mcam as mcam_lib
from repro.core.encodings import Encoding, make_encoding
from repro.core.mcam import MCAMConfig

Mode = str  # 'svss' | 'avss'


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """End-to-end VSS configuration."""

    encoding: str = "mtmc"
    cl: int = 8
    mode: Mode = "avss"
    mcam: MCAMConfig = dataclasses.field(default_factory=MCAMConfig)
    noisy: bool = True          # device/read noise on (paper-faithful)
    use_kernel: str = "auto"    # 'ref' | 'pallas' | 'mxu' | 'auto'
    query_chunk: int = 8        # reference-path chunking over queries

    @property
    def enc(self) -> Encoding:
        return make_encoding(self.encoding, self.cl)


def n_segments(d: int, string_len: int = mcam_lib.DEFAULT_STRING_LEN) -> int:
    return math.ceil(d / string_len)


def search_iterations(d: int, enc: Encoding, mode: Mode,
                      string_len: int = mcam_lib.DEFAULT_STRING_LEN) -> int:
    """Word-line cycles per query (paper Sec. 3.2)."""
    seg = n_segments(d, string_len)
    return seg if mode == "avss" else seg * enc.length


def strings_per_support(d: int, enc: Encoding,
                        string_len: int = mcam_lib.DEFAULT_STRING_LEN) -> int:
    return n_segments(d, string_len) * enc.length


# ---------------------------------------------------------------------------
# Layout helpers.
# ---------------------------------------------------------------------------


def _segment_dims(x: jax.Array, string_len: int) -> jax.Array:
    """(..., d) -> (..., n_seg, string_len), zero-padded."""
    d = x.shape[-1]
    seg = n_segments(d, string_len)
    pad = seg * string_len - d
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x.reshape(*x.shape[:-1], seg, string_len)


def layout_support_words(words: jax.Array,
                         string_len: int = mcam_lib.DEFAULT_STRING_LEN
                         ) -> jax.Array:
    """Code words (..., d, L) -> string grid (..., n_seg, L, string_len).

    The layout half of `layout_support`, split out so hardware-aware
    training can feed STE-encoded (float, differentiable) words through the
    SAME placement the engine programs at write time: pure pad/reshape/
    transpose, so gradients flow and the forward is bit-identical."""
    codes = jnp.moveaxis(words, -1, -2)          # (..., L, d)
    codes = _segment_dims(codes, string_len)     # (..., L, seg, sl)
    return jnp.moveaxis(codes, -3, -2)           # (..., seg, L, sl)


def layout_support(values: jax.Array, enc: Encoding,
                   string_len: int = mcam_lib.DEFAULT_STRING_LEN) -> jax.Array:
    """Quantized support values (N, d) -> string grid (N, n_seg, L, string_len).

    Padding dimensions store code 0 and are always searched with query word 0,
    contributing zero mismatch (and rho**0 resistance, as real pass cells do).

    This is WRITE-TIME work: MemoryStore.write materialises the grid once at
    programming time, and serving jits against the stored constant. The
    named_scope tags any traced call in compiled HLO so tests can assert the
    serve decode step does NOT re-lay out the store per step.
    """
    with jax.named_scope("layout_support"):
        return layout_support_words(enc.encode(values), string_len)


def layout_query(values: jax.Array, enc: Encoding, mode: Mode,
                 string_len: int = mcam_lib.DEFAULT_STRING_LEN) -> jax.Array:
    """Quantized query (B, d) -> word-line grid (B, n_seg, L_q, string_len).

    AVSS: L_q == 1 (values already in [0, 4)); SVSS: L_q == enc.length.
    """
    if mode == "avss":
        return _segment_dims(values, string_len)[..., :, None, :]
    return layout_support(values, enc, string_len)


# ---------------------------------------------------------------------------
# Reference search (pure jnp; the Pallas kernels mirror this bit-exactly).
# ---------------------------------------------------------------------------


def _string_ids(n: int, seg: int, L: int) -> jax.Array:
    """(N, seg, L) absolute string ids -- the noise-counter coordinates
    shared by the reference search, the rescore path and the episodic
    training forward (absolute ids are what make noise shard-invariant)."""
    return (jnp.arange(n, dtype=jnp.uint32)[:, None, None] * (seg * L)
            + jnp.arange(seg, dtype=jnp.uint32)[None, :, None] * L
            + jnp.arange(L, dtype=jnp.uint32)[None, None, :])


def votes_from_mismatch(mm: jax.Array, qidx: jax.Array, weights: jax.Array,
                        cfg: SearchConfig, thresholds: jax.Array, *,
                        noisy: bool | None = None,
                        noise_stream: jax.Array | None = None,
                        step_fn=None) -> tuple[jax.Array, jax.Array]:
    """The ONE mismatch-grid -> (votes, dist) forward.

    mm:   (..., N, seg, L, sl) per-cell mismatch levels (float; integer-
          valued in serving, STE-quantized in training).
    qidx: integer query coordinates broadcastable to mm.shape[:-1]
          (a scalar in the per-query reference search, an
          (B, 1, 1, 1) arange in the batched episodic forward) -- the
          absolute coordinates feeding the counter-based noise, so the
          same (query, string) pair draws the same noise everywhere.
    noisy:        overrides cfg.noisy when not None.
    noise_stream: optional extra leading noise coordinate (e.g. a
          training-step-derived stream id). None reproduces the serving
          noise EXACTLY; a stream id redraws fresh noise per step from
          the same counter-based family the hardware model uses.
    step_fn: optional differentiable sense-amp step (mcam.ste_step);
          forward-identical to the hard comparison.

    Serving (`RetrievalEngine.full`, ref backend) and training
    (`RetrievalEngine.episode_votes`) both run THIS function, which is the
    train/serve parity contract: same inputs -> bit-identical votes/dist.
    """
    n, seg, L, sl = mm.shape[-4:]
    if noisy is None:
        noisy = cfg.noisy
    if noisy:
        coords = (qidx, _string_ids(n, seg, L))
        if noise_stream is not None:
            coords = (noise_stream,) + coords
        cur = mcam_lib.string_current(mm, cfg.mcam, noise_idx=coords)
    else:
        cur = mcam_lib.string_current(mm, cfg.mcam)
    votes = mcam_lib.sa_votes(cur, cfg.mcam, thresholds, step_fn=step_fn)
    votes = (votes * weights[None, None, :]).sum((-1, -2))
    dist = (mm.sum(-1) * weights[None, None, :]).sum((-1, -2))
    return votes, dist


def _search_one_query(q_grid: jax.Array, s_grid: jax.Array, qidx: jax.Array,
                      weights: jax.Array, cfg: SearchConfig,
                      thresholds: jax.Array) -> tuple[jax.Array, jax.Array]:
    """q_grid (seg, Lq, sl); s_grid (N, seg, L, sl) -> votes (N,), dist (N,)."""
    mm = jnp.abs(q_grid[None].astype(jnp.int32) - s_grid.astype(jnp.int32))
    mm = mm.astype(jnp.float32)                      # (N, seg, L, sl)
    return votes_from_mismatch(mm, qidx, weights, cfg, thresholds)


def search_quantized(q_values: jax.Array, s_values: jax.Array,
                     cfg: SearchConfig) -> dict[str, jax.Array]:
    """Run the full MCAM search.

    q_values: (B, d) ints -- in [0, 4) for AVSS, [0, enc.levels) for SVSS.
    s_values: (N, d) ints in [0, enc.levels).
    Returns dict with votes (B, N), dist (B, N) (ideal digital distance) and
    iterations (python int).
    """
    # Dispatch lives in the engine layer (repro/engine); this wrapper keeps
    # the historical API for callers that think in terms of raw searches.
    # (The store-centric path is RetrievalEngine.search(MemoryStore...).)
    from repro.engine import RetrievalEngine
    return RetrievalEngine(cfg).full(q_values, s_values)


# ---------------------------------------------------------------------------
# Prediction heads.
# ---------------------------------------------------------------------------


def score_supports(result: dict[str, jax.Array]) -> jax.Array:
    """Votes with infinitesimal ideal-distance tie-breaking. (B, N).

    NOTE: only suitable where a scalar score is needed (class-vote SUMS in
    class_scores / HAT's CE loss). For ranking use best_support: the 1e-6
    epsilon falls below the f32 ulp once votes reach ~16, so argmax over
    this score silently loses the distance tie-break."""
    return result["votes"] - 1e-6 * result["dist"]


def best_support(result: dict[str, jax.Array]) -> jax.Array:
    """Argmax by (votes desc, ideal distance asc, index asc) -- the paper's
    retrieval rule with the vote tie EXACTLY broken by digital distance.
    Works on full (B, N) results and two-phase (B, k) candidate results."""
    votes, dist = result["votes"], result["dist"]
    top = votes.max(axis=-1, keepdims=True)
    return jnp.argmin(jnp.where(votes == top, dist, jnp.inf), axis=-1)


def predict_1nn(result: dict[str, jax.Array], labels: jax.Array) -> jax.Array:
    """Label of the most-similar support (the paper's retrieval rule)."""
    return labels[best_support(result)]


def class_scores(result: dict[str, jax.Array], labels: jax.Array,
                 n_classes: int) -> jax.Array:
    """Per-class vote sums (B, n_classes) with distance tie-breaking."""
    onehot = jax.nn.one_hot(labels, n_classes, dtype=result["votes"].dtype)
    return score_supports(result) @ onehot


def class_mean_votes(votes: jax.Array, labels: jax.Array,
                     n_classes: int) -> jax.Array:
    """Mean vote score per class (B, n_classes) -- HAT's episodic logits
    (paper Sec. 3.3), shared by `meta_loss` and the served evaluation so
    the two heads agree exactly when the underlying votes do."""
    onehot = jax.nn.one_hot(labels, n_classes, dtype=votes.dtype)
    counts = onehot.sum(0) + 1e-8
    return (votes @ onehot) / counts


def predict_class_vote(result, labels, n_classes) -> jax.Array:
    return jnp.argmax(class_scores(result, labels, n_classes), axis=-1)


def accuracy(pred: jax.Array, target: jax.Array) -> jax.Array:
    return (pred == target).mean()
