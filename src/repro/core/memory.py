"""MANN external memory backed by the simulated MCAM (sharded, first-class).

This is the module any backbone in the framework attaches to for many-class
few-shot heads / kNN memories: `write` stores controller embeddings (quantized
+ MTMC-projected at write time, as real MCAM programming would), `search` runs
AVSS and returns vote scores, and `distributed_search` shards the store across
an arbitrary mesh axis set with a local-top-k -> all-gather -> global-top-k
reduction (the block-parallel search a multi-chip MCAM deployment performs).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import avss as avss_lib
from repro.core.avss import SearchConfig
from repro.core.quantization import QuantSpec, fake_quant
from repro.kernels import ops as kernel_ops


@dataclasses.dataclass(frozen=True)
class MemoryConfig:
    capacity: int = 2048
    dim: int = 48
    search: SearchConfig = dataclasses.field(default_factory=SearchConfig)
    clip_std: float = 2.5


def init_memory(cfg: MemoryConfig) -> dict:
    enc = cfg.search.enc
    return {
        "values": jnp.zeros((cfg.capacity, cfg.dim), jnp.int32),
        "proj": jnp.zeros((cfg.capacity, 4 * cfg.dim), jnp.bfloat16),
        "labels": jnp.full((cfg.capacity,), -1, jnp.int32),
        "size": jnp.zeros((), jnp.int32),
        "lo": jnp.zeros((), jnp.float32),
        "hi": jnp.ones((), jnp.float32),
    }


def calibrate(state: dict, vectors: jax.Array, cfg: MemoryConfig) -> dict:
    """Set the quantization range from a sample of embeddings (std clipping,
    paper Sec. 3.3). Must run before the first write.

    The std range is clamped to the observed data extent, matching
    quantization.clip_range: one-sided distributions (post-ReLU controller
    embeddings) would otherwise spend half of the query's 4 levels on an
    empty half-range."""
    mu, sd = vectors.mean(), vectors.std() + 1e-8
    lo = jnp.maximum(mu - cfg.clip_std * sd, vectors.min())
    hi = jnp.minimum(mu + cfg.clip_std * sd, vectors.max() + 1e-8)
    return {**state, "lo": lo, "hi": hi}


def _quantize(x, levels, lo, hi):
    scale = (levels - 1) / (hi - lo)
    q = jnp.round((jnp.clip(x, lo, hi) - lo) * scale)
    return jnp.clip(q, 0, levels - 1).astype(jnp.int32)


def write(state: dict, vectors: jax.Array, labels: jax.Array,
          cfg: MemoryConfig) -> dict:
    """Program a batch of support embeddings into the store (ring buffer)."""
    enc = cfg.search.enc
    v = _quantize(vectors, enc.levels, state["lo"], state["hi"])
    proj = kernel_ops.support_projection(v, enc)
    n = vectors.shape[0]
    start = state["size"] % cfg.capacity
    idx = (start + jnp.arange(n)) % cfg.capacity
    return {
        **state,
        "values": state["values"].at[idx].set(v),
        "proj": state["proj"].at[idx].set(proj),
        "labels": state["labels"].at[idx].set(labels.astype(jnp.int32)),
        "size": state["size"] + n,
    }


def quantize_queries(state: dict, queries: jax.Array) -> jax.Array:
    return _quantize(queries, 4, state["lo"], state["hi"])


def search(state: dict, queries: jax.Array, cfg: MemoryConfig,
           two_phase: bool = False, k: int = 64,
           engine: "RetrievalEngine | None" = None) -> dict:
    """AVSS over the whole store. queries: (B, dim) float embeddings.

    Pass `engine` to reuse a configured RetrievalEngine (backend choice);
    by default one is built from cfg.search.
    """
    from repro.engine import RetrievalEngine
    eng = engine or RetrievalEngine(cfg.search)
    q = quantize_queries(state, queries)
    if two_phase:
        # mask unwritten slots out of the phase-1 shortlist; same expression
        # as distributed_search so the two paths stay bit-identical
        res = eng.two_phase(q, state["values"], k=k,
                            valid=state["labels"] >= 0)
        valid = res["indices"] < state["size"]
        votes = jnp.where(valid, res["votes"], -jnp.inf)
        labels = jnp.where(valid, state["labels"][res["indices"]], -1)
        return {**res, "votes": votes, "labels": labels}
    res = eng.full(q, state["values"])
    slot = jnp.arange(cfg.capacity)
    votes = jnp.where(slot[None, :] < state["size"], res["votes"], -jnp.inf)
    return {**res, "votes": votes,
            "labels": jnp.broadcast_to(state["labels"], votes.shape)}


def predict(result: dict) -> jax.Array:
    """1-NN label prediction from a (two-phase, full, or distributed) search
    result: max votes, vote ties broken exactly by the ideal digital
    distance (avss.best_support); masked slots carry -inf votes and lose."""
    best = avss_lib.best_support(result)
    return jnp.take_along_axis(result["labels"], best[:, None], 1)[:, 0]


# ---------------------------------------------------------------------------
# Distributed search: store rows sharded over mesh axes.
# ---------------------------------------------------------------------------


def shard_state(state: dict, mesh, axes) -> dict:
    """NamedSharding the store row-wise over `axes` (e.g. ('data','model'))."""
    row = jax.sharding.NamedSharding(mesh, P(axes))
    rep = jax.sharding.NamedSharding(mesh, P())
    put = lambda x, s: jax.device_put(x, s)
    return {
        "values": put(state["values"], row),
        "proj": put(state["proj"], row),
        "labels": put(state["labels"], row),
        "size": put(state["size"], rep),
        "lo": put(state["lo"], rep),
        "hi": put(state["hi"], rep),
    }


def distributed_search(state: dict, queries: jax.Array, cfg: MemoryConfig,
                       mesh, axes=("data", "model"), k: int = 16,
                       exact: bool = True) -> dict:
    """Block-parallel AVSS over the row-sharded store.

    exact=True (default, paper-faithful): each shard shortlists its rows on
    the MXU, runs the exact noisy vote rescore on its local candidates
    (global indices feed the noise counters), and the candidate sets are
    all-gathered and merged -- votes bit-identical to the single-device
    `search(..., two_phase=True)` for every shortlisted support.

    exact=False: ideal-digital-distance only (votes = -dist), the cheapest
    serving path. Either way, collective volume is O(B * k * shards),
    independent of capacity.
    """
    from repro.engine import sharded as sharded_lib
    q = quantize_queries(state, queries)
    if exact:
        # mask unwritten slots out of the phase-1 shortlist (labels, like
        # values, are row-sharded; < 0 marks an unwritten slot)
        res = sharded_lib.sharded_two_phase_search(
            q, state["values"], cfg.search, mesh, axes=axes, k=k,
            valid=state["labels"] >= 0)
        valid = res["indices"] < state["size"]
        votes = jnp.where(valid, res["votes"], -jnp.inf)
        labels = jnp.where(valid, state["labels"][res["indices"]], -1)
        return {**res, "votes": votes, "labels": labels}
    qrows = kernel_ops.query_onehot(q, jnp.float32)        # (B, 4d) replicated
    return sharded_lib.sharded_ideal_search(
        qrows, state["proj"], state["labels"], mesh, axes=axes, k=k)
