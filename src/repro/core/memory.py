"""Legacy MANN external-memory API: thin deprecation shims over MemoryStore.

The store itself moved to `repro.engine.store.MemoryStore` (an immutable
registered pytree whose `write` materialises the quantized values, the MTMC
LUT projection AND the string-grid layout at write time), and every search
goes through the unified `RetrievalEngine.search(store, queries,
SearchRequest) -> SearchResult` entry point. This module keeps the
pre-redesign dict-state functions working, bit-identically, for old callers:

  init_memory/calibrate/write   ->  MemoryStore.create/.calibrate/.write
  search                        ->  engine.search(store, q, mode=full|two_phase)
  distributed_search            ->  engine.search(store.shard(mesh, axes), q)
  shard_state                   ->  MemoryStore.shard

`search` and `distributed_search` emit a DeprecationWarning (once per
process per function); results remain bit-identical to the new API
(tests/test_deprecations.py).
"""

from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp

from repro.core import avss as avss_lib
from repro.core.avss import SearchConfig


@dataclasses.dataclass(frozen=True)
class MemoryConfig:
    capacity: int = 2048
    dim: int = 48
    search: SearchConfig = dataclasses.field(default_factory=SearchConfig)
    clip_std: float = 2.5


_WARNED: set = set()


def _warn_once(name: str, replacement: str) -> None:
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"repro.core.memory.{name} is deprecated; use {replacement}",
        DeprecationWarning, stacklevel=3)


def _store(state: dict, cfg: MemoryConfig):
    from repro.engine.store import MemoryStore
    return MemoryStore.from_state(state, cfg)


def init_memory(cfg: MemoryConfig) -> dict:
    """Legacy dict view of an empty MemoryStore (now also carries the
    write-time `s_grid` layout alongside `proj`)."""
    from repro.engine.store import MemoryStore
    return MemoryStore.create(cfg).to_state()


def calibrate(state: dict, vectors: jax.Array, cfg: MemoryConfig) -> dict:
    """Set the quantization range from a sample of embeddings (std clipping
    clamped to the data extent, paper Sec. 3.3). Must run before the first
    write."""
    return _store(state, cfg).calibrate(vectors).to_state()


def write(state: dict, vectors: jax.Array, labels: jax.Array,
          cfg: MemoryConfig) -> dict:
    """Program a batch of support embeddings into the store (ring buffer)."""
    return _store(state, cfg).write(vectors, labels).to_state()


def quantize_queries(state: dict, queries: jax.Array) -> jax.Array:
    from repro.engine.store import _quantize
    return _quantize(queries, 4, state["lo"], state["hi"])


def search(state: dict, queries: jax.Array, cfg: MemoryConfig,
           two_phase: bool = False, k: int = 64,
           engine: "RetrievalEngine | None" = None) -> dict:
    """DEPRECATED: AVSS over the whole store; use RetrievalEngine.search.

    Bit-identical to engine.search(MemoryStore.from_state(state, cfg),
    queries, SearchRequest(mode='two_phase' if two_phase else 'full', k)).
    """
    _warn_once("search", "RetrievalEngine.search(store, queries, "
                         "SearchRequest(...))")
    from repro.engine import RetrievalEngine, SearchRequest
    eng = engine or RetrievalEngine(cfg.search)
    req = SearchRequest(mode="two_phase" if two_phase else "full", k=k)
    return eng.search(_store(state, cfg), queries, req).asdict()


def predict(result) -> jax.Array:
    """1-NN label prediction from a search result (SearchResult or legacy
    dict): max votes, vote ties broken exactly by the ideal digital
    distance; masked slots carry -inf votes and lose."""
    if hasattr(result, "predict"):
        return result.predict()
    best = avss_lib.best_support(result)
    return jnp.take_along_axis(result["labels"], best[:, None], 1)[:, 0]


# ---------------------------------------------------------------------------
# Distributed search: store rows sharded over mesh axes.
# ---------------------------------------------------------------------------


def shard_state(state: dict, mesh, axes,
                cfg: MemoryConfig | None = None) -> dict:
    """Legacy dict view of MemoryStore.shard: row-shard the store over
    `axes`. Pass `cfg` for ragged (non-divisible) splits -- the pad rows'
    write-time layouts depend on the encoding, so a default config would
    pad with the wrong grid shape; divisible splits never pad and the
    historical 3-arg signature keeps working."""
    import numpy as np
    n, d = state["values"].shape
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    if cfg is None:
        if n % n_shards:
            raise ValueError(
                f"shard_state: {n} rows do not divide over {n_shards} "
                f"shards; ragged splits pad with encoding-dependent rows, "
                f"so pass cfg= (or use MemoryStore.shard directly)")
        cfg = MemoryConfig(capacity=n, dim=d)
    return _store(state, cfg).shard(mesh, axes).to_state()


def distributed_search(state: dict, queries: jax.Array, cfg: MemoryConfig,
                       mesh, axes=("data", "model"), k: int = 16,
                       exact: bool = True) -> dict:
    """DEPRECATED: block-parallel AVSS over the row-sharded store; use
    RetrievalEngine.search on a MemoryStore.shard(mesh, axes) store.

    exact=True (default, paper-faithful): per-shard MXU shortlist + exact
    noisy rescore with GLOBAL indices feeding the noise counters; candidate
    labels come from per-shard lookups folded into the all-gather -- votes
    bit-identical to the single-device two-phase search.
    exact=False: ideal-digital-distance only, the cheapest serving path.
    Either way, collective volume is O(B * k * shards), independent of
    capacity.
    """
    _warn_once("distributed_search",
               "RetrievalEngine.search(store.shard(mesh, axes), queries, "
               "SearchRequest(...))")
    from repro.engine import RetrievalEngine, SearchRequest
    # shard() is idempotent: it re-shards from the logical cfg.capacity
    # rows, so a state that shard_state already placed (possibly with
    # ragged pad rows) lands on the identical padded layout again
    store = _store(state, cfg).shard(mesh, tuple(axes))
    req = SearchRequest(mode="two_phase" if exact else "ideal", k=k)
    return RetrievalEngine(cfg.search).search(store, queries, req).asdict()
