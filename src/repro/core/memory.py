"""MANN external memory backed by the simulated MCAM (sharded, first-class).

This is the module any backbone in the framework attaches to for many-class
few-shot heads / kNN memories: `write` stores controller embeddings (quantized
+ MTMC-projected at write time, as real MCAM programming would), `search` runs
AVSS and returns vote scores, and `distributed_search` shards the store across
an arbitrary mesh axis set with a local-top-k -> all-gather -> global-top-k
reduction (the block-parallel search a multi-chip MCAM deployment performs).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import avss as avss_lib
from repro.core.avss import SearchConfig
from repro.core.quantization import QuantSpec, fake_quant
from repro.kernels import ops as kernel_ops


@dataclasses.dataclass(frozen=True)
class MemoryConfig:
    capacity: int = 2048
    dim: int = 48
    search: SearchConfig = dataclasses.field(default_factory=SearchConfig)
    clip_std: float = 2.5


def init_memory(cfg: MemoryConfig) -> dict:
    enc = cfg.search.enc
    return {
        "values": jnp.zeros((cfg.capacity, cfg.dim), jnp.int32),
        "proj": jnp.zeros((cfg.capacity, 4 * cfg.dim), jnp.bfloat16),
        "labels": jnp.full((cfg.capacity,), -1, jnp.int32),
        "size": jnp.zeros((), jnp.int32),
        "lo": jnp.zeros((), jnp.float32),
        "hi": jnp.ones((), jnp.float32),
    }


def calibrate(state: dict, vectors: jax.Array, cfg: MemoryConfig) -> dict:
    """Set the quantization range from a sample of embeddings (std clipping,
    paper Sec. 3.3). Must run before the first write."""
    mu, sd = vectors.mean(), vectors.std() + 1e-8
    return {**state, "lo": mu - cfg.clip_std * sd, "hi": mu + cfg.clip_std * sd}


def _quantize(x, levels, lo, hi):
    scale = (levels - 1) / (hi - lo)
    q = jnp.round((jnp.clip(x, lo, hi) - lo) * scale)
    return jnp.clip(q, 0, levels - 1).astype(jnp.int32)


def write(state: dict, vectors: jax.Array, labels: jax.Array,
          cfg: MemoryConfig) -> dict:
    """Program a batch of support embeddings into the store (ring buffer)."""
    enc = cfg.search.enc
    v = _quantize(vectors, enc.levels, state["lo"], state["hi"])
    proj = kernel_ops.support_projection(v, enc)
    n = vectors.shape[0]
    start = state["size"] % cfg.capacity
    idx = (start + jnp.arange(n)) % cfg.capacity
    return {
        **state,
        "values": state["values"].at[idx].set(v),
        "proj": state["proj"].at[idx].set(proj),
        "labels": state["labels"].at[idx].set(labels.astype(jnp.int32)),
        "size": state["size"] + n,
    }


def quantize_queries(state: dict, queries: jax.Array) -> jax.Array:
    return _quantize(queries, 4, state["lo"], state["hi"])


def search(state: dict, queries: jax.Array, cfg: MemoryConfig,
           two_phase: bool = False, k: int = 64) -> dict:
    """AVSS over the whole store. queries: (B, dim) float embeddings."""
    q = quantize_queries(state, queries)
    if two_phase:
        res = kernel_ops.two_phase_search(q, state["values"], cfg.search, k=k)
        valid = res["indices"] < state["size"]
        votes = jnp.where(valid, res["votes"], -jnp.inf)
        labels = jnp.where(valid, state["labels"][res["indices"]], -1)
        return {**res, "votes": votes, "labels": labels}
    res = avss_lib.search_quantized(q, state["values"], cfg.search)
    slot = jnp.arange(cfg.capacity)
    votes = jnp.where(slot[None, :] < state["size"], res["votes"], -jnp.inf)
    return {**res, "votes": votes,
            "labels": jnp.broadcast_to(state["labels"], votes.shape)}


def predict(result: dict) -> jax.Array:
    """1-NN label prediction from a (two-phase or full) search result."""
    score = result["votes"] - 1e-6 * jnp.where(
        jnp.isfinite(result["votes"]), result["dist"], 0.0)
    best = jnp.argmax(score, axis=-1)
    return jnp.take_along_axis(result["labels"], best[:, None], 1)[:, 0]


# ---------------------------------------------------------------------------
# Distributed search: store rows sharded over mesh axes.
# ---------------------------------------------------------------------------


def shard_state(state: dict, mesh, axes) -> dict:
    """NamedSharding the store row-wise over `axes` (e.g. ('data','model'))."""
    row = jax.sharding.NamedSharding(mesh, P(axes))
    rep = jax.sharding.NamedSharding(mesh, P())
    put = lambda x, s: jax.device_put(x, s)
    return {
        "values": put(state["values"], row),
        "proj": put(state["proj"], row),
        "labels": put(state["labels"], row),
        "size": put(state["size"], rep),
        "lo": put(state["lo"], rep),
        "hi": put(state["hi"], rep),
    }


def distributed_search(state: dict, queries: jax.Array, cfg: MemoryConfig,
                       mesh, axes=("data", "model"), k: int = 16) -> dict:
    """Block-parallel AVSS: each shard searches its rows with the MXU LUT
    kernel-equivalent einsum, local top-k, then a global top-k after
    all-gathering the (tiny) candidate sets. Collective volume is
    O(B * k * shards), independent of capacity."""
    from jax.experimental.shard_map import shard_map
    enc = cfg.search.enc
    q = quantize_queries(state, queries)
    qrows = kernel_ops.query_onehot(q, jnp.float32)        # (B, 4d) replicated

    def local(qr, proj, labels):
        # proj: (N_loc, 4d); ideal digital distance on local rows
        dist = qr @ proj.astype(jnp.float32).T             # (B, N_loc)
        dist = jnp.where(labels[None, :] < 0, jnp.inf, dist)  # empty slots
        kk = min(k, proj.shape[0])
        neg, idx = jax.lax.top_k(-dist, kk)
        cand_lab = labels[idx]                             # (B, kk)
        # gather candidates from every shard
        ax = axes[0] if len(axes) == 1 else axes
        d_all = jax.lax.all_gather(-neg, ax, tiled=False)  # (S, B, kk) or nested
        l_all = jax.lax.all_gather(cand_lab, ax, tiled=False)
        d_all = d_all.reshape(-1, *neg.shape)              # (S, B, kk)
        l_all = l_all.reshape(-1, *neg.shape)
        d_flat = jnp.moveaxis(d_all, 0, 1).reshape(neg.shape[0], -1)
        l_flat = jnp.moveaxis(l_all, 0, 1).reshape(neg.shape[0], -1)
        best = jnp.argsort(d_flat, axis=-1)[:, :k]
        return (jnp.take_along_axis(d_flat, best, 1),
                jnp.take_along_axis(l_flat, best, 1))

    dist, labels = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(axes), P(axes)),
        out_specs=(P(), P()),
        check_rep=False,
    )(qrows, state["proj"], state["labels"])
    return {"dist": dist, "labels": labels, "votes": -dist}
