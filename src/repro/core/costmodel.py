"""Analytic latency/energy model of the MCAM search (paper Table 2 / Fig. 9).

Iteration counts are exact (Sec. 3.2). Absolute rates/energies are anchored to
the paper's Table 2 throughput numbers, which back-solve to a block search
rate of 20k word-line cycles/s on the measured device of Tseng et al. [14]:

    Omniglot  SVSS 64 it -> 312.5 /s      AVSS 2 it -> 10000 /s   (32x)
    CUB       SVSS 500 it -> 40 /s        AVSS 20 it -> 1000 /s   (25x)

Energy is reported in normalised units of one string search (one string, one
word-line cycle); a whole-block cycle costs ``n_strings`` units. This keeps
Fig. 9's x-axis shape exact while absolute Joules stay a device constant.
"""

from __future__ import annotations

import math

from repro.core import avss as avss_lib
from repro.core.encodings import Encoding
from repro.core.mcam import DEFAULT_STRING_LEN

BLOCK_SEARCH_RATE_HZ = 20_000.0  # word-line cycles per second (from Table 2)
E_STRING_SEARCH = 1.0            # normalised energy unit


def iterations(d: int, enc: Encoding, mode: str,
               string_len: int = DEFAULT_STRING_LEN) -> int:
    return avss_lib.search_iterations(d, enc, mode, string_len)


def throughput_searches_per_s(d: int, enc: Encoding, mode: str,
                              string_len: int = DEFAULT_STRING_LEN) -> float:
    return BLOCK_SEARCH_RATE_HZ / iterations(d, enc, mode, string_len)


def strings_used(d: int, enc: Encoding, n_supports: int,
                 string_len: int = DEFAULT_STRING_LEN) -> int:
    return avss_lib.strings_per_support(d, enc, string_len) * n_supports


def energy_per_query(d: int, enc: Encoding, mode: str, n_supports: int,
                     string_len: int = DEFAULT_STRING_LEN) -> float:
    """Energy of one query: every active string is sensed once per word-line
    cycle in which it participates.

    AVSS: all L strings of a segment share one cycle -> each string sensed
    once -> E = strings_used. SVSS: strings are sensed in their own cycles ->
    also once each. The encodings differ through strings_used (= L * n_seg *
    N), reproducing Fig. 9's x-axis ordering: longer codes cost more energy.
    """
    del mode
    return E_STRING_SEARCH * strings_used(d, enc, n_supports, string_len)


def blocks_required(d: int, enc: Encoding, n_supports: int,
                    string_len: int = DEFAULT_STRING_LEN,
                    block_strings: int = 131072) -> int:
    return math.ceil(strings_used(d, enc, n_supports, string_len) / block_strings)
