"""Hardware-Aware Training (paper Sec. 3.3, Fig. 8).

Two-stage flow:
  1. ``pretrain_step``   -- controller + linear classifier, plain CE over the
     full training label set (transferable features).
  2. ``meta_train_step`` -- episodic N-way K-shot training with the FULL MCAM
     simulator in the forward pass: asymmetric fake-quant (4-level query /
     l-level support), std clipping, MTMC encoding with a 1/CL
     straight-through gradient, series-resistance string currents with
     Gaussian device + read noise, sense-amp thresholding with a
     sigmoid-gradient STE, and vote accumulation. CE is taken on the per-class
     vote scores, so the controller learns representations that survive the
     hardware.

Everything is functional JAX: ``apply_fn(params, images) -> embeddings``.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import avss as avss_lib
from repro.core import mcam as mcam_lib
from repro.core.avss import SearchConfig
from repro.core.encodings import MAX_MISMATCH
from repro.core.quantization import QuantSpec, fake_quant, quantize_asymmetric


# ---------------------------------------------------------------------------
# Straight-through pieces.
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def ste_step(x: jax.Array, tau: float) -> jax.Array:
    """Hard step forward; sigmoid gradient backward (paper Fig. 8(c))."""
    return (x > 0).astype(jnp.float32)


def _step_fwd(x, tau):
    return (x > 0).astype(jnp.float32), x


def _step_bwd(tau, x, g):
    s = jax.nn.sigmoid(x / tau)
    return (g * s * (1 - s) / tau,)


ste_step.defvjp(_step_fwd, _step_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def mtmc_word_ste(v: jax.Array, c: int, cl: int) -> jax.Array:
    """c-th MTMC code word of (integer-valued) v; backward slope 1/CL
    (paper Fig. 8(b): the discrete encoder's trend line)."""
    x = jnp.floor(v / cl)
    n = v - x * cl
    return jnp.clip(x + (c >= cl - n), 0, MAX_MISMATCH)


def _mtmc_fwd(v, c, cl):
    return mtmc_word_ste(v, c, cl), None


def _mtmc_bwd(c, cl, _, g):
    return (g / cl,)


mtmc_word_ste.defvjp(_mtmc_fwd, _mtmc_bwd)


# ---------------------------------------------------------------------------
# Differentiable MCAM forward simulation.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HATConfig:
    search: SearchConfig = dataclasses.field(default_factory=SearchConfig)
    clip_std: float = 2.5
    sa_tau: float = 0.02       # sigmoid-STE temperature for the SA step
    temperature: float = 0.15  # softmax temperature on class vote scores


def _encode_words_ste(v: jax.Array, cfg: SearchConfig) -> jax.Array:
    """(..., d) integer-valued values -> (..., d, L) words with STE grads."""
    if cfg.encoding == "mtmc":
        words = [mtmc_word_ste(v, c, cfg.cl) for c in range(cfg.cl)]
        return jnp.stack(words, axis=-1)
    # Non-MTMC HAT falls back to exact encode with unit STE on values.
    enc = cfg.enc
    hard = enc.encode(v.astype(jnp.int32)).astype(jnp.float32)
    return hard + (v[..., None] - jax.lax.stop_gradient(v[..., None])) / enc.length


def simulate_mcam(q_emb: jax.Array, s_emb: jax.Array, s_labels: jax.Array,
                  n_classes: int, hat: HATConfig, key: jax.Array,
                  noisy: bool = True) -> jax.Array:
    """Differentiable end-to-end MCAM search -> (B, n_classes) class scores.

    q_emb (B, dim), s_emb (N, dim) are float controller outputs.
    """
    cfg = hat.search
    enc = cfg.enc
    sl = cfg.mcam.string_len

    if cfg.mode == "avss":
        q, v = quantize_asymmetric(q_emb, s_emb, enc.levels, hat.clip_std, 4)
    else:
        q, _, rng = fake_quant(s_emb, QuantSpec(enc.levels, hat.clip_std))
        v = q
        q, _, _ = fake_quant(q_emb, QuantSpec(enc.levels, hat.clip_std), rng)

    s_words = _encode_words_ste(v, cfg)                      # (N, d, L)
    if cfg.mode == "avss":
        q_words = q[..., None]                               # (B, d, 1)
    else:
        q_words = _encode_words_ste(q, cfg)                  # (B, d, L)

    # (B, N, d, L) per-word mismatch; |.| keeps gradients to both sides.
    mm = jnp.abs(q_words[:, None] - s_words[None])
    # segment dims into strings: (B, N, L, seg, sl)
    mm = jnp.moveaxis(mm, -1, -2)
    mm = avss_lib._segment_dims(mm, sl)
    mm = jnp.moveaxis(mm, -3, -2)                            # (B, N, seg, L, sl)

    mcfg = cfg.mcam
    if noisy:
        kd, kr = jax.random.split(key)
        dn = jax.random.normal(kd, mm.shape)
        m_eff = jnp.clip(mm + mcfg.sigma_device * dn, 0.0, float(MAX_MISMATCH))
    else:
        m_eff = mm
    r = jnp.power(jnp.float32(mcfg.rho), m_eff).sum(-1)
    cur = jnp.float32(sl) / r
    if noisy:
        cur = cur * (1.0 + mcfg.sigma_read * jax.random.normal(kr, cur.shape))

    th = jnp.asarray(mcfg.thresholds())
    votes = ste_step(cur[..., None] - th, hat.sa_tau).sum(-1)  # (B,N,seg,L)
    w = enc.weights_array()
    votes = (votes * w[None, None, None, :]).sum((-1, -2))     # (B, N)

    onehot = jax.nn.one_hot(s_labels, n_classes, dtype=votes.dtype)
    counts = onehot.sum(0) + 1e-8
    return (votes @ onehot) / counts                           # mean vote/class


# ---------------------------------------------------------------------------
# Training steps.
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def pretrain_loss(params, batch, apply_fn):
    """Stage 1: CE over the full training class set via a linear head."""
    emb = apply_fn(params["backbone"], batch["image"])
    logits = emb @ params["head"]["w"] + params["head"]["b"]
    return cross_entropy(logits, batch["label"])


def meta_loss(params, episode, apply_fn, hat: HATConfig, key, noisy=True):
    """Stage 2: episodic CE through the simulated MCAM."""
    s_emb = apply_fn(params["backbone"], episode["support_images"])
    q_emb = apply_fn(params["backbone"], episode["query_images"])
    scores = simulate_mcam(q_emb, s_emb, episode["support_labels"],
                           episode["n_way"], hat, key, noisy=noisy)
    return cross_entropy(scores / hat.temperature, episode["query_labels"])


def make_train_steps(apply_fn, hat: HATConfig, optimizer):
    """Returns jitted (pretrain_step, meta_step) closures over an optimizer
    with (init, update) in the optax-like protocol from repro.optim."""

    @jax.jit
    def pretrain_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(pretrain_loss)(params, batch, apply_fn)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
        return params, opt_state, loss

    @jax.jit
    def meta_step(params, opt_state, episode, key):
        loss, grads = jax.value_and_grad(meta_loss)(
            params, episode, apply_fn, hat, key)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
        return params, opt_state, loss

    return pretrain_step, meta_step
