"""Hardware-Aware Training (paper Sec. 3.3, Fig. 8).

Two-stage flow:
  1. ``pretrain_step``   -- controller + linear classifier, plain CE over the
     full training label set (transferable features).
  2. ``meta_train_step`` -- episodic N-way K-shot training with the FULL MCAM
     simulator in the forward pass: asymmetric fake-quant (4-level query /
     l-level support), std clipping, MTMC encoding with a 1/CL
     straight-through gradient, series-resistance string currents with
     counter-hash device + read noise, sense-amp thresholding with a
     sigmoid-gradient STE, and vote accumulation. CE is taken on the
     per-class vote scores, so the controller learns representations that
     survive the hardware.

Since the train/serve unification the differentiable forward is NOT a
private re-implementation: `simulate_mcam` delegates to
`RetrievalEngine.episode_votes`, which composes the same shared primitives
the serving engine traces (`quantization.affine_quantize`,
`encodings.encode_words_ste` -> `avss.layout_support_words`,
`avss.votes_from_mismatch` -> `mcam.string_current`/`sa_votes`), with the
straight-through estimators wrapped AROUND them. The moved STEs keep
re-exports here (their canonical homes: `quantization.ste_round`,
`encodings.mtmc_word_ste`, `mcam.ste_step` -- see docs/migration.md);
training and serving therefore cannot drift -- the in-episode noiseless
votes are bit-identical to `engine.search` on a store programmed with the
same supports (tests/test_train_serve_parity.py).

Everything is functional JAX: ``apply_fn(params, images) -> embeddings``.

A 2-way toy episode through the full simulator:

>>> import jax, jax.numpy as jnp
>>> from repro.core.avss import SearchConfig
>>> from repro.core.hat import HATConfig, simulate_mcam
>>> hat = HATConfig(search=SearchConfig("mtmc", cl=2, mode="avss",
...                                     use_kernel="ref"))
>>> q = jnp.eye(2); s = jnp.eye(2); labels = jnp.array([0, 1])
>>> scores = simulate_mcam(q, s, labels, 2, hat, jax.random.PRNGKey(0),
...                        noisy=False)
>>> scores.shape                      # (queries, classes) vote logits
(2, 2)
>>> bool((scores[0, 0] > scores[0, 1]) & (scores[1, 1] > scores[1, 0]))
True
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.avss import SearchConfig
# Canonical homes of the straight-through estimators (migration re-exports:
# callers that imported them from here keep working).
from repro.core.encodings import encode_words_ste, mtmc_word_ste  # noqa: F401
from repro.core.mcam import ste_step  # noqa: F401
from repro.core.quantization import (QuantSpec, fake_quant,  # noqa: F401
                                     quantize_asymmetric)


@dataclasses.dataclass(frozen=True)
class HATConfig:
    search: SearchConfig = dataclasses.field(default_factory=SearchConfig)
    clip_std: float = 2.5
    sa_tau: float = 0.02       # sigmoid-STE temperature for the SA step
    temperature: float = 0.15  # softmax temperature on class vote scores


def simulate_mcam(q_emb: jax.Array, s_emb: jax.Array, s_labels: jax.Array,
                  n_classes: int, hat: HATConfig, key: jax.Array,
                  noisy: bool = True) -> jax.Array:
    """Differentiable end-to-end MCAM search -> (B, n_classes) class scores.

    q_emb (B, dim), s_emb (N, dim) are float controller outputs. Thin
    wrapper over `RetrievalEngine.episode_scores` -- the engine's
    differentiable episodic entry point, kept here under its historical
    name for existing callers.
    """
    from repro.engine import RetrievalEngine
    return RetrievalEngine(hat.search).episode_scores(
        q_emb, s_emb, s_labels, n_classes, clip_std=hat.clip_std,
        sa_tau=hat.sa_tau, key=key, noisy=noisy)


# ---------------------------------------------------------------------------
# Training steps.
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def pretrain_loss(params, batch, apply_fn):
    """Stage 1: CE over the full training class set via a linear head."""
    emb = apply_fn(params["backbone"], batch["image"])
    logits = emb @ params["head"]["w"] + params["head"]["b"]
    return cross_entropy(logits, batch["label"])


def meta_loss(params, episode, apply_fn, hat: HATConfig, key, noisy=True):
    """Stage 2: episodic CE through the simulated MCAM."""
    s_emb = apply_fn(params["backbone"], episode["support_images"])
    q_emb = apply_fn(params["backbone"], episode["query_images"])
    scores = simulate_mcam(q_emb, s_emb, episode["support_labels"],
                           episode["n_way"], hat, key, noisy=noisy)
    return cross_entropy(scores / hat.temperature, episode["query_labels"])


def make_train_steps(apply_fn, hat: HATConfig, optimizer):
    """Returns jitted (pretrain_step, meta_step) closures over an optimizer
    with (init, update) in the optax-like protocol from repro.optim.

    The launch layer builds its two-stage trainer (with mesh placement and
    per-stage optimizers) via `repro.launch.steps.make_hat_train_steps`;
    this simpler historical helper remains for single-host callers."""

    @jax.jit
    def pretrain_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(pretrain_loss)(params, batch, apply_fn)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
        return params, opt_state, loss

    @jax.jit
    def meta_step(params, opt_state, episode, key):
        loss, grads = jax.value_and_grad(meta_loss)(
            params, episode, apply_fn, hat, key)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
        return params, opt_state, loss

    return pretrain_step, meta_step
