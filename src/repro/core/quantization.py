"""Quantization-aware training primitives (paper Sec. 3.2/3.3).

Implements the modified QAT of Jacob et al. [23] used by the paper:

* std-based clipping of controller outputs before quantization (outliers
  disproportionately widen the quantization range),
* straight-through-estimator rounding,
* ASYMMETRIC schemes: the query is quantized to 4 levels (one MCAM word)
  while supports get ``levels`` (e.g. 3*CL+1 for MTMC) -- the controller
  learns to be robust to the coarse query that AVSS searches with.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.custom_vjp
def ste_round(x: jax.Array) -> jax.Array:
    return jnp.round(x)


def _ste_round_fwd(x):
    return jnp.round(x), None


def _ste_round_bwd(_, g):
    return (g,)


ste_round.defvjp(_ste_round_fwd, _ste_round_bwd)


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    levels: int
    clip_std: float = 2.5  # clip to mean +/- clip_std * std before scaling


def affine_quantize(x: jax.Array, levels: int, lo, hi,
                    round_fn=jnp.round) -> jax.Array:
    """THE quantizer: clip to [lo, hi], scale to [0, levels), round, clamp.

    This single function is shared by training and serving --
    `fake_quant` calls it with `round_fn=ste_round` (STE gradients), and
    `MemoryStore.write` / `quantize_queries` call it with the default
    hard round. Both produce the SAME forward values bit-for-bit, which
    is one leg of the train/serve parity contract
    (tests/test_train_serve_parity.py)."""
    scale = (levels - 1) / (hi - lo)
    q = round_fn((jnp.clip(x, lo, hi) - lo) * scale)
    return jnp.clip(q, 0, levels - 1)


def clip_range(x: jax.Array, clip_std: float) -> tuple[jax.Array, jax.Array]:
    """Std-determined clip range, computed batch-wide and detached (the range
    is a calibration statistic, not a learnable path). Clamped to the actual
    data extent so one-sided distributions (e.g. post-ReLU embeddings) don't
    waste quantization levels on an empty half-range."""
    xs = jax.lax.stop_gradient(x)
    mu = xs.mean()
    sd = xs.std() + 1e-8
    lo = jnp.maximum(mu - clip_std * sd, xs.min())
    hi = jnp.minimum(mu + clip_std * sd, xs.max() + 1e-8)
    return lo, hi


def fake_quant(x: jax.Array, spec: QuantSpec,
               rng_range: tuple[jax.Array, jax.Array] | None = None
               ) -> tuple[jax.Array, jax.Array, tuple[jax.Array, jax.Array]]:
    """Quantize to [0, levels) with STE.

    Returns (q_int_like, x_dequant, (lo, hi)): q is float-typed but integer
    valued (gradients flow via STE); x_dequant maps back to the input scale.
    """
    lo, hi = clip_range(x, spec.clip_std) if rng_range is None else rng_range
    scale = (spec.levels - 1) / (hi - lo)
    q = affine_quantize(x, spec.levels, lo, hi, round_fn=ste_round)
    return q, q / scale + lo, (lo, hi)


def quantize_asymmetric(query: jax.Array, support: jax.Array,
                        support_levels: int, clip_std: float = 2.5,
                        query_levels: int = 4,
                        rng: tuple[jax.Array, jax.Array] | None = None):
    """Paper's asymmetric QAT: a SHARED clip range (from the support
    statistics, the stored distribution) but different level counts.
    `rng` overrides the range (e.g. a MemoryStore's calibrated (lo, hi),
    so the episodic training forward quantizes exactly like serving).
    Returns (q_query, q_support) integer-valued float arrays."""
    if rng is None:
        rng = clip_range(jnp.concatenate([support.ravel(), query.ravel()]),
                         clip_std)
    qq, _, _ = fake_quant(query, QuantSpec(query_levels, clip_std), rng)
    qs, _, _ = fake_quant(support, QuantSpec(support_levels, clip_std), rng)
    return qq, qs
