"""Sharded, atomic, async, MESH-ELASTIC checkpointing.

Design (multi-host-correct, exercised single-host on CPU):

* Each host writes only its ADDRESSABLE shards: files
  ``<leaf-id>.<start0>_<start1>....npy`` keyed by the shard's global start
  offsets, so any host layout produces a complete, non-overlapping tile set.
* A JSON manifest stores the flattened tree paths, global shapes/dtypes and
  the step. The manifest is written LAST, after all tensor tiles, and the
  whole step directory is staged under ``.tmp-<step>-<host>`` then atomically
  renamed -- a crashed/preempted writer can never produce a directory that
  looks complete.
* Restore rebuilds each GLOBAL array from tiles and re-shards it onto the
  TARGET sharding via jax.make_array_from_callback => restoring onto a
  different mesh shape / device count (elastic restart) or onto abstract
  eval_shape targets is free.
* Async: `save(..., blocking=False)` snapshots to host RAM (device_get) and
  writes on a daemon thread; `wait()` joins. GC keeps the newest `keep` steps.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np

_SEP = "//"


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append(_SEP.join(parts))
        leaves.append(leaf)
    return names, leaves, treedef


def _leaf_id(i: int) -> str:
    return f"leaf{i:05d}"


def save(directory: str, step: int, tree, *, blocking: bool = True,
         keep: int = 3) -> threading.Thread | None:
    """Write checkpoint for `step`. Returns the writer thread if async."""
    names, leaves, _ = _flatten_with_names(tree)
    host = jax.process_index()
    # Snapshot addressable shards NOW (so training can proceed).
    tiles = []  # (fname, np.ndarray)
    meta = []
    for i, leaf in enumerate(leaves):
        arr = leaf
        meta.append({"name": names[i], "shape": list(np.shape(arr)),
                     "dtype": str(arr.dtype)})
        if hasattr(arr, "addressable_shards"):
            seen = set()
            for sh in arr.addressable_shards:
                start = tuple(idx.start or 0 for idx in sh.index) \
                    if sh.index != (Ellipsis,) else (0,) * arr.ndim
                if start in seen:
                    continue  # replicated copies: write once per host
                seen.add(start)
                key = "_".join(map(str, start)) or "0"
                tiles.append((f"{_leaf_id(i)}.{key}.npy",
                              np.asarray(jax.device_get(sh.data))))
        else:
            tiles.append((f"{_leaf_id(i)}.0.npy", np.asarray(arr)))

    def _write():
        tmp = os.path.join(directory, f".tmp-{step}-{host}")
        final = os.path.join(directory, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        for fname, data in tiles:
            np.save(os.path.join(tmp, fname), data)
        if host == 0:
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "leaves": meta}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(directory, keep)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _gc(directory: str, keep: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(directory, d, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, target, *, step: int | None = None,
            shardings=None):
    """Rebuild `target`-structured tree. `target` may hold arrays or
    ShapeDtypeStructs; `shardings` (same structure, optional) re-shards onto
    any mesh -- elastic restore."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    names, leaves, treedef = _flatten_with_names(target)
    sh_leaves = (jax.tree_util.tree_leaves(shardings)
                 if shardings is not None else [None] * len(leaves))
    by_name = {m["name"]: i for i, m in enumerate(manifest["leaves"])}
    out = []
    for name, leaf, shd in zip(names, leaves, sh_leaves):
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        i = by_name[name]
        info = manifest["leaves"][i]
        shape = tuple(info["shape"])
        # assemble global array from tiles. numpy round-trips ml_dtypes
        # (bfloat16 etc.) as raw void records -- re-view with the manifest
        # dtype before use.
        dt = np.dtype(info["dtype"])

        def fix(arr):
            return arr.view(dt) if arr.dtype.kind == "V" else arr

        tiles = [f for f in os.listdir(d) if f.startswith(_leaf_id(i) + ".")]
        if len(tiles) == 1 and tiles[0].endswith(".0.npy") and "_" not in \
                tiles[0][len(_leaf_id(i)) + 1:-4]:
            full = fix(np.load(os.path.join(d, tiles[0])))
        else:
            full = np.zeros(shape, dtype=dt)
            for fname in tiles:
                key = fname[len(_leaf_id(i)) + 1:-4]
                start = tuple(int(x) for x in key.split("_"))
                part = fix(np.load(os.path.join(d, fname)))
                sl = tuple(slice(s, s + n) for s, n in zip(start, part.shape))
                full[sl] = part
        full = full.reshape(shape).astype(dt)
        if shd is not None:
            arr = jax.make_array_from_callback(
                shape, shd, lambda idx, _f=full: _f[idx])
        else:
            arr = jax.numpy.asarray(full)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Train-loop front end: async save every N steps + preemption save."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, step: int, tree, force: bool = False):
        if not force and (self.every <= 0 or step % self.every):
            return
        self.wait()
        self._pending = save(self.directory, step, tree, blocking=False,
                             keep=self.keep)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def latest_step(self):
        return latest_step(self.directory)

    def restore(self, target, shardings=None, step=None):
        return restore(self.directory, target, step=step,
                       shardings=shardings)
