"""Unified retrieval engine: one API over the ref / Pallas / MXU-LUT backends.

`RetrievalEngine` is the single dispatch point for every search path in the
framework (the `use_kernel` branching formerly inlined in `core/avss.py`,
`core/memory.py` and `kernels/ops.py`):

  full                exact noisy MCAM search over the whole store
  two_phase           MXU shortlist by ideal digital distance + exact noisy
                      rescore of the top-k candidates
  sharded_two_phase   the same two-phase pipeline with the store row-sharded
                      over mesh axes -- votes bit-identical to the
                      single-device two_phase for every shortlisted support
"""

from repro.engine.backends import (BACKENDS, kernels_available,
                                   resolve_backend)
from repro.engine.engine import RetrievalEngine
from repro.engine.sharded import (sharded_ideal_search,
                                  sharded_two_phase_search)

__all__ = [
    "BACKENDS",
    "RetrievalEngine",
    "kernels_available",
    "resolve_backend",
    "sharded_ideal_search",
    "sharded_two_phase_search",
]
