"""Unified retrieval engine: one API over the ref / Pallas / MXU-LUT backends.

The serving contract (PR 2) is store-centric:

  MemoryStore          the programmed MCAM memory as an immutable registered
                       pytree -- quantized values, labels, quant range, ring
                       state, plus the WRITE-TIME `proj` (LUT projection) and
                       `s_grid` (string-grid) layouts, and its own sharding
                       (`shard(mesh, axes)` row-shards, padding ragged splits
                       with label -1 rows).
  SearchRequest        what to search: mode ('full' | 'two_phase' | 'ideal'),
                       k, backend override, shard-axes override.
  SearchResult         votes / dist / indices / labels / iterations -- one
                       typed result for every mode, backend and sharding.
  RetrievalEngine      `search(store, queries, request) -> SearchResult`, the
                       single dispatch point. The raw-array methods (`full`,
                       `two_phase`, `sharded_two_phase`) remain underneath
                       for callers without a store; all paths are
                       bit-identical (tests/test_engine.py).
  TenantStore          N per-tenant stores stacked along a leading tenant
                       axis, searched in one coalesced device batch through
                       `RetrievalEngine.search_tenants` (PR 9) -- one jit
                       cache entry for ANY tenant count, per-tenant results
                       bit-identical to solo `search` (tests/test_tenant.py).
  router / ShardPager  the memory hierarchy (PR 10): every partitioned store
                       maintains a per-shard class-centroid sketch at write
                       time; `SearchRequest.nprobe` scores it and searches
                       only the top-p shards (bit-identical to brute force
                       over the visited shards), and `ShardPager` serves a
                       `shard(n_shards=..., residency="host")` store whose
                       cold shards live in host memory, paging visited ones
                       through a fixed set of device slots.
"""

from repro.engine.api import SearchRequest, SearchResult
from repro.engine.backends import (BACKENDS, kernels_available,
                                   resolve_backend)
from repro.engine.engine import IDEAL_FUSED_MIN_ROWS, RetrievalEngine
from repro.engine.pager import ShardPager
from repro.engine.router import (ROUTER_BUCKETS, build_sketch, route_scores,
                                 sketch_centroids, top_shards)
from repro.engine.sharded import (sharded_ideal_search,
                                  sharded_two_phase_search)
from repro.engine.store import MemoryStore
from repro.engine.tenant import TenantStore, tenant_query_rank

__all__ = [
    "BACKENDS",
    "IDEAL_FUSED_MIN_ROWS",
    "MemoryStore",
    "ROUTER_BUCKETS",
    "RetrievalEngine",
    "SearchRequest",
    "SearchResult",
    "ShardPager",
    "TenantStore",
    "build_sketch",
    "kernels_available",
    "resolve_backend",
    "route_scores",
    "sharded_ideal_search",
    "sharded_two_phase_search",
    "sketch_centroids",
    "tenant_query_rank",
    "top_shards",
]
