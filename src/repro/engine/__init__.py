"""Unified retrieval engine: one API over the ref / Pallas / MXU-LUT backends.

The serving contract (PR 2) is store-centric:

  MemoryStore          the programmed MCAM memory as an immutable registered
                       pytree -- quantized values, labels, quant range, ring
                       state, plus the WRITE-TIME `proj` (LUT projection) and
                       `s_grid` (string-grid) layouts, and its own sharding
                       (`shard(mesh, axes)` row-shards, padding ragged splits
                       with label -1 rows).
  SearchRequest        what to search: mode ('full' | 'two_phase' | 'ideal'),
                       k, backend override, shard-axes override.
  SearchResult         votes / dist / indices / labels / iterations -- one
                       typed result for every mode, backend and sharding.
  RetrievalEngine      `search(store, queries, request) -> SearchResult`, the
                       single dispatch point. The raw-array methods (`full`,
                       `two_phase`, `sharded_two_phase`) remain underneath
                       for callers without a store; all paths are
                       bit-identical (tests/test_engine.py).
  TenantStore          N per-tenant stores stacked along a leading tenant
                       axis, searched in one coalesced device batch through
                       `RetrievalEngine.search_tenants` (PR 9) -- one jit
                       cache entry for ANY tenant count, per-tenant results
                       bit-identical to solo `search` (tests/test_tenant.py).
"""

from repro.engine.api import SearchRequest, SearchResult
from repro.engine.backends import (BACKENDS, kernels_available,
                                   resolve_backend)
from repro.engine.engine import IDEAL_FUSED_MIN_ROWS, RetrievalEngine
from repro.engine.sharded import (sharded_ideal_search,
                                  sharded_two_phase_search)
from repro.engine.store import MemoryStore
from repro.engine.tenant import TenantStore, tenant_query_rank

__all__ = [
    "BACKENDS",
    "IDEAL_FUSED_MIN_ROWS",
    "MemoryStore",
    "RetrievalEngine",
    "SearchRequest",
    "SearchResult",
    "TenantStore",
    "kernels_available",
    "resolve_backend",
    "sharded_ideal_search",
    "sharded_two_phase_search",
    "tenant_query_rank",
]
