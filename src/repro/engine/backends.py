"""Backend registry and resolution for the retrieval engine.

Backends (every one produces bit-identical results -- the choice is purely a
performance/hardware decision, see the parity suite in tests/test_engine.py):

  ref     pure-jnp reference (kernels/ref.py semantics); always available.
  pallas  fused Pallas VPU search kernel (kernels/mcam_search.py) for the
          full search; Pallas MXU LUT matmul for shortlists.
  mxu     alias of `pallas` for the full search; for two-phase shortlists it
          names the unfused LUT matmul + lax.top_k pipeline.
  fused   two-phase shortlists via the fused distance+top-k Pallas kernel
          (kernels/shortlist.py); full search as `pallas`.
"""

from __future__ import annotations

import functools

BACKENDS = ("ref", "pallas", "mxu", "fused")
KERNEL_BACKENDS = ("pallas", "mxu", "fused")


@functools.cache
def kernels_available() -> bool:
    """True when the Pallas kernel package imports (optional dependency)."""
    try:
        from repro.kernels import ops  # noqa: F401
        return True
    except Exception:
        return False


def resolve_backend(backend: str = "auto", use_kernel: str = "auto") -> str:
    """Resolve an engine-level override plus a SearchConfig preference.

    `backend` (the engine's own setting) wins over `use_kernel` (the
    SearchConfig field kept for backwards compatibility); "auto" defers.
    """
    for choice in (backend, use_kernel):
        if choice != "auto":
            if choice not in BACKENDS:
                raise ValueError(
                    f"unknown backend {choice!r}; expected one of "
                    f"{BACKENDS + ('auto',)}")
            return choice
    return "pallas" if kernels_available() else "ref"
