"""Phase-0 coarse router: per-shard summary sketches over class centroids.

The hierarchy's top level (ROADMAP item 2, IVF-flavored).  Every
partitioned :class:`~repro.engine.store.MemoryStore` carries a
write-time sketch -- per shard, ``ROUTER_BUCKETS`` class-bucket
centroids in the store's already-calibrated integer domain -- and a
routed search (``SearchRequest.nprobe=p``) scores the sketch with ONE
small dense matmul before dispatching phase 1/2 to the top-p shards
only.

Design constraints, inherited from the serving contract:

* **Integer-exact.** Sketch sums/counts are int32; centroids are exact
  round-half-up integer levels, so the scatter write path and the
  shard-local write-through maintain bit-identical sketches, and
  ``save/restore`` reproduces them deterministically.
* **Scatter-free.** ``bucket_sums`` accumulates through a one-hot int32
  matmul (``jax.ops.segment_sum`` lowers to scatter, which the
  multi-shard write-through contract forbids -- see
  analysis/registry.py `MemoryStore.write` cells).
* **Same mask spelling.** Empty buckets carry ``SHORTLIST_MASK_PENALTY``
  exactly like masked support rows in the shortlist, so they can never
  out-rank a shard with real rows.
* The sketch matmul runs under ``jax.named_scope("router_sketch")`` --
  the contract registry asserts the tag appears iff routing is engaged
  (``nprobe < n_shards``), mirroring the fused-kernel tag.

>>> import jax.numpy as jnp
>>> vals = jnp.array([[0, 9], [2, 9], [8, 1], [8, 3]])
>>> labs = jnp.array([0, 0, 1, 1])
>>> sums, counts = build_sketch(vals, labs, n_shards=2, n_buckets=2)
>>> sums.shape, counts.shape          # (shards, buckets, dim), (S, R)
((2, 2, 2), (2, 2))
>>> [int(x) for x in sums[0, 0]]      # shard 0, bucket 0: rows 0+1 summed
[2, 18]
>>> cent = sketch_centroids(sums, counts, levels=10)
>>> [int(x) for x in cent[0, 0]]      # exact round-half-up mean levels
[1, 9]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.encodings import Encoding
from repro.kernels import ops as kernel_ops
from repro.kernels.shortlist import SHORTLIST_MASK_PENALTY

#: class buckets per shard sketch (label % ROUTER_BUCKETS).  Small on
#: purpose: the whole sketch is S * R * d int32, and routing cost is one
#: (B, 4d) x (4d, S*R) matmul -- negligible next to one shard's phase 1.
ROUTER_BUCKETS = 8


def bucket_sums(values: jax.Array, labels: jax.Array,
                n_buckets: int = ROUTER_BUCKETS
                ) -> tuple[jax.Array, jax.Array]:
    """Per-bucket int32 (sums (R, d), counts (R,)) of valid rows.

    Rows bucket by ``label % n_buckets``; label -1 (pad/mask sentinel)
    rows contribute nothing.  Accumulation is a one-hot int32 matmul:
    exact, and scatter-free so it is legal inside the multi-shard
    write-through (whose compiled HLO must contain no scatter under any
    spelling).
    """
    lab = labels.astype(jnp.int32)
    valid = lab >= 0
    bucket = jnp.where(valid, lab % n_buckets, 0)
    onehot = ((bucket[:, None] == jnp.arange(n_buckets, dtype=jnp.int32))
              & valid[:, None]).astype(jnp.int32)          # (N, R)
    sums = onehot.T @ values.astype(jnp.int32)             # (R, d)
    counts = jnp.sum(onehot, axis=0)                       # (R,)
    return sums, counts


def build_sketch(values: jax.Array, labels: jax.Array, n_shards: int,
                 n_buckets: int = ROUTER_BUCKETS
                 ) -> tuple[jax.Array, jax.Array]:
    """Full-store sketch: (S, R, d) int32 sums and (S, R) int32 counts.

    Rows partition into ``n_shards`` contiguous blocks (the same row
    blocks ``MemoryStore.shard`` lays out), each sketched independently.
    Deterministic function of (values, labels), so recomputing after
    ``restore`` reproduces the saved store's sketch bit-identically.
    """
    n = values.shape[0]
    if n % n_shards:
        raise ValueError(f"{n} rows do not split into {n_shards} shards")
    rows = n // n_shards
    vals = values.reshape(n_shards, rows, values.shape[1])
    labs = labels.reshape(n_shards, rows)
    return jax.vmap(lambda v, l: bucket_sums(v, l, n_buckets))(vals, labs)


def sketch_centroids(sums: jax.Array, counts: jax.Array,
                     levels: int) -> jax.Array:
    """Integer bucket centroids: exact round-half-up mean, clamped to the
    store's calibrated level grid [0, levels).  Empty buckets yield level
    0 -- harmless, because :func:`route_scores` masks them out."""
    c = jnp.maximum(counts, 1).astype(jnp.int32)[..., None]
    cent = (2 * sums + c) // (2 * c)                   # round-half-up
    return jnp.clip(cent, 0, levels - 1).astype(jnp.int32)


def route_scores(q_values: jax.Array, sketch_sums: jax.Array,
                 sketch_counts: jax.Array, enc: Encoding) -> jax.Array:
    """(B, S) router scores: per shard, the min exact LUT distance from
    each query to the shard's valid bucket centroids.

    The centroids live in the store's calibrated integer domain, so they
    project through the SAME write-time LUT (`support_projection`) as
    real support rows and score with the same one-hot matmul as the
    dense phase-1 -- one (B, 4d) x (4d, S*R) dot.  Empty buckets carry
    ``SHORTLIST_MASK_PENALTY`` (the shortlist's own mask spelling), so a
    shard of pure padding can never beat a shard with real rows.
    """
    with jax.named_scope("router_sketch"):
        s, r, d = sketch_sums.shape
        cent = sketch_centroids(sketch_sums, sketch_counts, enc.levels)
        proj = kernel_ops.support_projection(cent.reshape(s * r, d), enc)
        q1h = kernel_ops.query_onehot(q_values, jnp.float32)
        dist = q1h @ proj.astype(jnp.float32).T            # (B, S*R)
        mask = jnp.where(sketch_counts > 0, 0.0,
                         SHORTLIST_MASK_PENALTY).reshape(s * r)
        return jnp.min(dist.reshape(-1, s, r) + mask.reshape(s, r)[None],
                       axis=-1)


def top_shards(scores: jax.Array, nprobe: int) -> jax.Array:
    """Top-``nprobe`` shard ids per query, ASCENDING shard id.

    Selection follows the engine's lex rule -- smallest score first,
    ties to the lowest shard id (`lax.top_k` positional tie-break on the
    negated scores).  The ascending sort afterwards is what makes the
    routed search's concatenated candidate blocks globally
    index-ordered, so its (distance, index) merge is bit-identical to
    brute force restricted to the visited shards.
    """
    _, idx = jax.lax.top_k(-scores, nprobe)
    return jnp.sort(idx, axis=-1).astype(jnp.int32)
