"""The RetrievalEngine: one `search()` entry point, one typed contract.

`search(store, queries, SearchRequest) -> SearchResult` subsumes every
retrieval path in the framework: full exact search, the two-phase
shortlist+rescore serving pipeline, and the cheap ideal-distance path --
unsharded or row-sharded (the store carries its own mesh/axes, see
repro/engine/store.py). The pre-redesign methods (`full`, `two_phase`,
`sharded_two_phase`) remain as the raw-array layer underneath and for
callers that do not hold a MemoryStore.

All backends share one semantics contract (kernels/ref.py): for a given
(SearchConfig, query batch, support store) the votes and distances are
bit-identical regardless of backend or sharding. Two facts make this cheap
to guarantee:

* Phase-1 shortlist distances are integer-valued: AVSS LUT entries are small
  integers, query one-hots are 0/1, and every backend accumulates in f32
  (exact for integers < 2**24), so the shortlist distance is the same exact
  float no matter how the reduction is ordered or which unit computes it.
* Phase-2 noise is a counter-based hash of ABSOLUTE (query, string, cell)
  coordinates, so the noisy rescore of a support does not depend on which
  shard or kernel tile evaluates it.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import TYPE_CHECKING, Any, Hashable, Sequence

import jax
import jax.numpy as jnp

from repro.core import avss as avss_lib
from repro.core import encodings as enc_lib
from repro.core import mcam as mcam_lib
from repro.core import quantization as quant_lib
from repro.core.avss import SearchConfig
from repro.engine.api import SearchRequest, SearchResult
from repro.engine.backends import resolve_backend
from repro.kernels import ref as ref_kernels

if TYPE_CHECKING:
    from jax.sharding import Mesh

    from repro.engine.store import MemoryStore
    from repro.engine.tenant import TenantStore


def _noise_stream(key: jax.Array | int | None) -> jax.Array | None:
    """Fold a PRNG key (typed or legacy uint32), array or int into one
    uint32 noise-stream coordinate for the counter-based hardware noise.
    None passes through -- the stream-less coordinates are EXACTLY the
    serving ones, so episode_votes(key=None) is bit-identical to the
    noisy `full` search."""
    if key is None:
        return None
    if isinstance(key, jax.Array) and jnp.issubdtype(key.dtype,
                                                    jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    arr = jnp.atleast_1d(jnp.asarray(key)).astype(jnp.uint32).ravel()
    s = jnp.uint32(0x9E3779B9)
    for i in range(arr.shape[0]):
        s = mcam_lib._mix(s ^ arr[i])
    return s

# Default row threshold above which shortlists (the `ideal` mode and the
# two-phase phase 1 -- unsharded, or PER SHARD-LOCAL BLOCK when sharded)
# route through the fused Pallas shortlist kernel (kernels/shortlist.py)
# instead of materialising the dense (B, N) distance matrix -- HBM traffic
# drops from O(B*N) to O(B*k + N*4d), bit-identically (the fused kernel
# reproduces lax.top_k's (distance, row) order exactly, ties included).
# 1024 is the MEASURED dense-vs-fused crossover from the PR-6 shortlist
# rework (BENCH_shortlist.json / benchmarks/autotune_shortlist.py, CPU
# interpret mode). Still a knob, not a constant: override without code
# change via RetrievalEngine(fused_min_rows=...) or
# SearchRequest.fused_min_rows, and re-run the autotune sweep on real TPU
# to rewrite it there (ROADMAP item 3 note).
IDEAL_FUSED_MIN_ROWS = 1024


@dataclasses.dataclass(frozen=True)
class RetrievalEngine:
    """Dispatches AVSS/SVSS searches to a selected backend.

    cfg:      the end-to-end search configuration (encoding, MCAM physics,
              noise). `cfg.use_kernel` is honoured as a fallback preference.
    backend:  'auto' | 'ref' | 'pallas' | 'mxu' | 'fused'; overrides
              cfg.use_kernel when not 'auto'.
    fused_min_rows: row threshold for the fused-shortlist dispatch (per
              shard-local block on sharded stores); 'fused' always fuses
              and 'ref' never does. `SearchRequest.fused_min_rows`
              overrides this per request.
    """

    cfg: SearchConfig
    backend: str = "auto"
    fused_min_rows: int = IDEAL_FUSED_MIN_ROWS

    @property
    def resolved_backend(self) -> str:
        return resolve_backend(self.backend, self.cfg.use_kernel)

    def _cached_replace(self, key: Hashable,
                        **changes: Any) -> "RetrievalEngine":
        """dataclasses.replace cached per instance: per-request overrides
        return the SAME engine object on every call -- no rebuild, and
        closures keyed on the engine (jit caches) keep hitting."""
        cache = self.__dict__.get("_backend_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_backend_cache", cache)
        eng = cache.get(key)
        if eng is None:
            eng = dataclasses.replace(self, **changes)
            cache[key] = eng
        return eng

    def with_backend(self, backend: str) -> "RetrievalEngine":
        """Engine with a per-request backend override (cached, see
        `_cached_replace`); 'auto' and the current backend return self."""
        if backend in ("auto", self.backend):
            return self
        return self._cached_replace(backend, backend=backend)

    def with_noisy(self, noisy: bool | None) -> "RetrievalEngine":
        """Engine whose SearchConfig has `noisy` overridden (cached); None
        and the current setting return self. This is what threads
        `SearchRequest.noisy` through every mode/backend/sharding -- e.g.
        serving a noiseless forward for a train/serve parity check without
        rebuilding configs."""
        if noisy is None or noisy == self.cfg.noisy:
            return self
        return self._cached_replace(
            ("noisy", noisy), cfg=dataclasses.replace(self.cfg, noisy=noisy))

    def _fused_threshold(self, request: SearchRequest | None = None) -> int:
        """Effective fused-shortlist row threshold: the request override
        when set, else this engine's `fused_min_rows`."""
        if request is not None and request.fused_min_rows is not None:
            return request.fused_min_rows
        return self.fused_min_rows

    # -- unified entry point -----------------------------------------------

    def search(self, store: MemoryStore, queries: jax.Array,
               request: SearchRequest | None = None) -> SearchResult:
        """Search a programmed MemoryStore: the one serving entry point.

        store:    repro.engine.store.MemoryStore. Its write-time `proj` and
                  `s_grid` layouts are used directly, so nothing re-runs
                  `layout_support`/`support_projection` under jit; its
                  (mesh, axes) metadata selects the sharded dispatch.
        queries:  (B, dim) float embeddings (quantized with the store's
                  calibrated range) or pre-quantized ints (passed through).
        request:  SearchRequest (mode, k, backend, axes, fused threshold);
                  default two-phase.

        Results are bit-identical to the raw-array methods below for every
        mode/backend/sharding (tests/test_engine.py, tests/test_store.py)
        -- including whether a shortlist ran the fused Pallas kernel or
        the dense reference (`fused_min_rows` is purely a perf knob).

        >>> import jax.numpy as jnp
        >>> from repro.core.avss import SearchConfig
        >>> from repro.engine import (MemoryStore, RetrievalEngine,
        ...                           SearchRequest)
        >>> cfg = SearchConfig("mtmc", cl=4, mode="avss", use_kernel="ref")
        >>> sv = jnp.array([[0, 3], [5, 5], [9, 7]])   # quantized supports
        >>> store = MemoryStore.from_quantized(sv, jnp.array([7, 8, 9]), cfg)
        >>> res = RetrievalEngine(cfg).search(          # query words in [0,4)
        ...     store, jnp.array([[1, 1]]), SearchRequest(mode="ideal", k=1))
        >>> res.predict().tolist()          # nearest support is row 1
        [8]
        """
        req = request if request is not None else SearchRequest()
        eng = self.with_backend(req.backend).with_noisy(req.noisy)
        if store.residency == "host":
            raise ValueError(
                "RetrievalEngine.search: this store's shards live in host "
                "memory (shard(..., residency='host')); search it through "
                "repro.engine.pager.ShardPager, which pages the visited "
                "shards into device memory -- or re-shard with "
                "residency='device'.")
        q = store.quantize_queries(queries)
        valid = store.valid
        iters = eng._iterations(q.shape[-1])

        # phase-0 routing: engaged iff the request asks for FEWER shards
        # than the store has (nprobe=None and nprobe >= n_shards fall
        # through to the exhaustive paths below, byte-for-byte)
        if (req.nprobe is not None and req.mode != "full"
                and req.nprobe < store.n_shards):
            return eng._search_routed(store, q, req)

        if store.mesh is None or req.mode == "full":
            return eng._search_unsharded(store, q, req)

        # per-shard shortlists share the unsharded dispatch rule: the
        # fused Pallas kernel engages once a shard's LOCAL rows reach
        # the threshold (engine/sharded._use_fused)
        axes = req.axes if req.axes is not None else store.axes
        fmr = eng._fused_threshold(req)
        backend = eng.resolved_backend
        if req.mode == "two_phase":
            from repro.engine import sharded
            res = sharded.sharded_two_phase_search(
                q, store.values, eng.cfg, store.mesh, axes=axes,
                k=req.k, valid=valid, labels=store.labels,
                s_grid=store.s_grid, proj=store.proj,
                packed=store.proj_packed, pack_bits=store.pack_bits,
                backend=backend, fused_min_rows=fmr)
            # labels come from the per-shard fold (-1 on empty/pad
            # rows): mask their votes without any global gather
            votes = jnp.where(res["labels"] >= 0, res["votes"],
                              -jnp.inf)
            return SearchResult(votes, res["dist"], res["indices"],
                                res["labels"], res["iterations"])
        from repro.engine import sharded
        from repro.kernels import ops as kernel_ops
        q1h = kernel_ops.query_onehot(q, jnp.float32)
        res = sharded.sharded_ideal_search(
            q1h, store.proj, store.labels, store.mesh, axes=axes,
            k=req.k, backend=backend, fused_min_rows=fmr,
            packed=store.proj_packed, pack_bits=store.pack_bits,
            enc=eng.cfg.enc)
        votes = jnp.where(res["labels"] >= 0, res["votes"], -jnp.inf)
        return SearchResult(votes, res["dist"], res["indices"],
                            res["labels"], iters)

    def _search_unsharded(self, store: MemoryStore, q: jax.Array,
                          req: SearchRequest,
                          noise_qidx: jax.Array | None = None
                          ) -> SearchResult:
        """The unsharded (single-block) search body shared by `search` and
        `search_tenants`: `self` must already carry the request's backend
        and noisy overrides, `q` is already quantized. `noise_qidx` (B,)
        overrides the per-query noise coordinates (see `full`); `search`
        leaves it None (arange(B)), `search_tenants` passes each query's
        rank within its tenant group so the vmapped dispatch is
        bit-identical to per-tenant solo calls."""
        valid = store.valid
        iters = self._iterations(q.shape[-1])
        if req.mode == "full":
            res = self.full(q, store.values, s_grid=store.s_grid,
                            noise_qidx=noise_qidx)
            votes = jnp.where(valid[None, :], res["votes"], -jnp.inf)
            indices = jnp.broadcast_to(
                jnp.arange(store.capacity, dtype=jnp.int32), votes.shape)
            labels = jnp.broadcast_to(store.labels, votes.shape)
            return SearchResult(votes, res["dist"], indices, labels,
                                res["iterations"])
        if req.mode == "two_phase":
            res = self.two_phase(q, store.values, k=req.k, valid=valid,
                                 s_grid=store.s_grid, proj=store.proj,
                                 packed=store.proj_packed,
                                 pack_bits=store.pack_bits,
                                 fused_min_rows=self._fused_threshold(req),
                                 noise_qidx=noise_qidx)
            labels = store.labels[res["indices"]]      # -1 on empty slots
            votes = jnp.where(labels >= 0, res["votes"], -jnp.inf)
            return SearchResult(votes, res["dist"], res["indices"], labels,
                                res["iterations"])
        # ideal: top-k by the exact integer-valued digital distance against
        # the write-time LUT projection. Masked rows carry the integer-exact
        # SHORTLIST_MASK_PENALTY (the same contract as two_phase / the
        # sharded ideal path), so every route below is bit-identical. Large
        # stores stream through the fused Pallas shortlist kernel -- HBM
        # O(B*k + N*4d) instead of the dense (B, N) matrix; small stores and
        # the ref backend keep the dense matmul as the readable reference.
        from repro.kernels import ops as kernel_ops
        k = min(req.k, store.capacity)
        backend = self.resolved_backend
        if backend != "ref" and (store.capacity >= self._fused_threshold(req)
                                 or backend == "fused"):
            dist, idx = kernel_ops.lut_shortlist(
                q, store.values, self.cfg.enc, k, valid=valid,
                proj=store.proj, packed=store.proj_packed,
                pack_bits=store.pack_bits)
        else:
            # same dense block shortlist the sharded paths use per shard
            from repro.engine.sharded import _local_shortlist
            q1h = kernel_ops.query_onehot(q, jnp.float32)
            dist, idx = _local_shortlist(q1h, store.proj, valid, k,
                                         fused=False)
        labels = store.labels[idx]
        votes = jnp.where(labels >= 0, -dist, -jnp.inf)
        return SearchResult(votes, dist, idx, labels, iters)

    # -- routed (phase-0) search -------------------------------------------

    def _search_routed(self, store: MemoryStore, q: jax.Array,
                       req: SearchRequest) -> SearchResult:
        """nprobe-routed search over a partitioned store: score the
        write-time router sketch (engine/router.py, one small matmul under
        the "router_sketch" scope), then run phase 1/2 on the top-p shard
        blocks only -- bit-identical to brute force restricted to the
        visited shards (tests/test_router.py). `self` already carries the
        request's backend/noisy overrides; `q` is already quantized."""
        from repro.engine import router as router_lib
        s = store.n_shards
        rows = store.capacity // s
        scores = router_lib.route_scores(q, store.sketch_sums,
                                         store.sketch_counts, self.cfg.enc)
        sids = router_lib.top_shards(scores, int(req.nprobe or 0))
        # per-shard block tables (S, rows, ...); on a mesh-sharded store
        # these reshapes stay sharded and XLA inserts the per-query block
        # gathers (the single-device / logical-partition path is the one
        # the routed contract cells pin collective-free)
        packed_t = (None if store.proj_packed is None
                    else store.proj_packed.reshape(s, rows, -1))
        return self._routed_block_search(
            q, sids, jnp.arange(s, dtype=jnp.int32),
            store.proj.reshape(s, rows, -1), packed_t,
            store.s_grid.reshape((s, rows) + store.s_grid.shape[1:]),
            store.labels.reshape(s, rows), req, store.pack_bits)

    def _routed_block_search(self, q: jax.Array, slot_ids: jax.Array,
                             shard_of: jax.Array, proj_t: jax.Array,
                             packed_t: jax.Array | None,
                             sgrid_t: jax.Array, labels_t: jax.Array,
                             req: SearchRequest, pack_bits: int,
                             noise_qidx: jax.Array | None = None
                             ) -> SearchResult:
        """Shared routed-search core over per-shard block tables.

        `search` calls it with the store's own (S, rows, ...) tables and
        `shard_of = arange(S)`; `engine/pager.ShardPager` calls it with
        its device-RESIDENT slot tables (M, rows, ...) and the slot ->
        global-shard map. Per query, `slot_ids` (B, p) names the visited
        table rows ORDERED BY ASCENDING GLOBAL SHARD ID -- concatenating
        the blocks in that order makes the candidate axis globally
        index-ordered, so the shared `_local_shortlist` (fused kernel or
        dense matmul, same mask penalty) reproduces the exhaustive
        search's (distance, global index) lex order exactly on the
        visited subset. Phase 2 rescores with GLOBAL indices feeding the
        noise counters, so routed votes equal the full search's votes for
        every shortlisted candidate.
        """
        from repro.engine.sharded import _local_shortlist, _use_fused
        from repro.kernels import ops as kernel_ops
        cfg = self.cfg
        assert cfg.mode == "avss", "routed search shortlists the AVSS LUT"
        p = slot_ids.shape[1]
        rows = proj_t.shape[1]
        rows_vis = p * rows
        k = min(req.k, rows_vis)
        fused = _use_fused(self.resolved_backend, rows_vis,
                           self._fused_threshold(req))
        two_phase = req.mode == "two_phase"
        q1h = kernel_ops.query_onehot(q, jnp.float32)
        q_grid = avss_lib.layout_query(q, cfg.enc, "avss",
                                       cfg.mcam.string_len)
        weights = cfg.enc.weights_array()
        thresholds = jnp.asarray(cfg.mcam.thresholds())
        if noise_qidx is None:
            noise_qidx = jnp.arange(q.shape[0], dtype=jnp.uint32)

        def one(q1h_b: jax.Array, qgrid_b: jax.Array, sl_b: jax.Array,
                qi_b: jax.Array
                ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
            lab_vis = labels_t[sl_b].reshape(rows_vis)
            proj_vis = proj_t[sl_b].reshape(rows_vis, -1)
            pk_vis = (packed_t[sl_b].reshape(rows_vis, -1)
                      if fused and packed_t is not None else None)
            dist, li = _local_shortlist(q1h_b[None], proj_vis,
                                        lab_vis >= 0, k, fused=fused,
                                        packed=pk_vis, pack_bits=pack_bits)
            # local candidate position -> global store row: visited blocks
            # are ascending-shard-ordered, block i covers global rows
            # [shard_of[sl_b[i]] * rows, ...)
            gidx = shard_of[sl_b][li // rows] * rows + li % rows
            lab = lab_vis[li]
            if two_phase:
                sg_vis = sgrid_t[sl_b].reshape((rows_vis,)
                                               + sgrid_t.shape[2:])
                votes = kernel_ops.rescore_shortlist(
                    qgrid_b[None], sg_vis, li, weights, cfg, thresholds,
                    noise_idx=gidx, noise_qidx=qi_b[None])
            else:
                votes = -dist
            votes = jnp.where(lab >= 0, votes, -jnp.inf)
            return votes[0], dist[0], gidx[0], lab[0]

        votes, dist, indices, labels = jax.vmap(one)(
            q1h, q_grid, slot_ids, noise_qidx.astype(jnp.uint32))
        return SearchResult(votes, dist, indices, labels,
                            self._iterations(q.shape[-1]))

    # -- multi-tenant dispatch ---------------------------------------------

    def search_tenants(self, tstore: TenantStore, queries: jax.Array,
                       tenant_ids: jax.Array,
                       request: SearchRequest | None = None) -> SearchResult:
        """One compiled search over a batch of queries from MANY tenants.

        tstore:     repro.engine.tenant.TenantStore -- N per-tenant
                    MemoryStores stacked along a leading tenant axis.
        queries:    (B, dim) float embeddings (quantized per query against
                    the OWNING tenant's calibrated range) or pre-quantized
                    ints (passed through).
        tenant_ids: (B,) int -- the owning tenant of each query. Traced
                    data, NOT static: batches with different tenant mixes
                    hit the same compiled program (one jit cache entry per
                    tenant count/batch shape, asserted by the
                    `single_jit_entry_across_tenants` contract cell).
        request:    SearchRequest; `mode`/`backend`/`k`/`fused_min_rows`/
                    `noisy` all apply (axes is meaningless here -- tenant
                    stacks are unsharded).

        Dispatch: gather each query's tenant leaves out of the stacked
        store and vmap the single-query unsharded search over the batch --
        full/two_phase/ideal x ref/mxu/fused (the Pallas kernels batch
        under vmap), bit-identical per tenant to solo `engine.search` on
        `tstore.tenant(i)` for queries grouped in batch order (the noise
        coordinates are each query's rank within its tenant group, exactly
        the solo batch positions; tests/test_tenant.py). Results span the
        stack's padded capacity: a ragged tenant's pad rows behave like
        never-written slots (-inf votes, label -1, mask penalty).

        >>> import jax.numpy as jnp
        >>> from repro.core.avss import SearchConfig
        >>> from repro.engine import (MemoryStore, RetrievalEngine,
        ...                           SearchRequest, TenantStore)
        >>> cfg = SearchConfig("mtmc", cl=4, mode="avss", use_kernel="ref")
        >>> a = MemoryStore.from_quantized(
        ...     jnp.array([[0, 3], [9, 7]]), jnp.array([1, 2]), cfg)
        >>> b = MemoryStore.from_quantized(
        ...     jnp.array([[5, 5]]), jnp.array([7]), cfg)
        >>> res = RetrievalEngine(cfg).search_tenants(
        ...     TenantStore.stack([a, b]), jnp.array([[3, 2], [3, 2]]),
        ...     jnp.array([0, 1]), SearchRequest(mode="ideal", k=1))
        >>> res.predict().tolist()     # same query, per-tenant answers
        [2, 7]
        """
        from repro.engine import tenant as tenant_lib
        req = request if request is not None else SearchRequest()
        eng = self.with_backend(req.backend).with_noisy(req.noisy)
        tenant_ids = jnp.asarray(tenant_ids).astype(jnp.int32)
        q = tstore.quantize_queries(queries, tenant_ids)
        rank = tenant_lib.tenant_query_rank(tenant_ids)
        view = tstore.query_view(tenant_ids)

        def one(store_b: MemoryStore, q_b: jax.Array,
                rank_b: jax.Array) -> SearchResult:
            return eng._search_unsharded(store_b, q_b[None], req,
                                         noise_qidx=rank_b[None])

        res = jax.vmap(one)(view, q, rank)
        # drop the inner singleton query axis: (B, 1, K) -> (B, K)
        return SearchResult(res.votes[:, 0], res.dist[:, 0],
                            res.indices[:, 0], res.labels[:, 0],
                            res.iterations)

    # -- differentiable episodic forward (hardware-aware training) ---------

    def episode_votes(self, q_emb: jax.Array, s_emb: jax.Array, *,
                      clip_std: float = 2.5, sa_tau: float = 0.02,
                      key: jax.Array | int | None = None,
                      noisy: bool | None = None,
                      rng_range: tuple[jax.Array, jax.Array] | None = None
                      ) -> dict[str, jax.Array]:
        """Differentiable end-to-end MCAM forward on FLOAT embeddings.

        This is the training twin of `search(mode='full')`: asymmetric
        STE fake-quant, STE word encoding, the write-time string layout,
        and the `votes_from_mismatch` physics -- the SAME shared functions
        the serving path traces, with the straight-through estimators
        (`quantization.ste_round`, `encodings.encode_words_ste`,
        `mcam.ste_step`) wrapped AROUND them rather than re-implemented.
        Consequence (the train/serve parity contract,
        tests/test_train_serve_parity.py): given the same embeddings and
        quantization range, the returned votes/dist are BIT-IDENTICAL to
        `search` on a store programmed with the same supports -- noiseless,
        and even noisy when `key=None` (the counter-based noise then uses
        exactly the serving coordinates).

        q_emb (B, dim), s_emb (N, dim): float controller outputs.
        clip_std:  std-clipping for the shared quantization range.
        sa_tau:    sigmoid-STE temperature of the sense-amp step.
        key:       optional PRNG key / int folded into an extra noise-
                   stream coordinate (fresh hardware noise per train step);
                   None reproduces the serving noise exactly.
        noisy:     overrides cfg.noisy when not None.
        rng_range: optional explicit (lo, hi) quantization range, e.g. a
                   MemoryStore's calibrated range.
        Returns {votes (B, N), dist (B, N), iterations}.

        >>> import jax, jax.numpy as jnp
        >>> from repro.core.avss import SearchConfig
        >>> from repro.core.memory import MemoryConfig
        >>> from repro.engine import (MemoryStore, RetrievalEngine,
        ...                           SearchRequest)
        >>> cfg = SearchConfig("mtmc", cl=4, mode="avss", use_kernel="ref")
        >>> eng = RetrievalEngine(cfg)
        >>> s = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(0), (6, 8)))
        >>> q = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (3, 8)))
        >>> votes = eng.episode_votes(q, s, noisy=False)["votes"]
        >>> store = MemoryStore.create(
        ...     MemoryConfig(capacity=6, dim=8, search=cfg)
        ... ).calibrate(jnp.concatenate([s.ravel(), q.ravel()]))
        >>> store = store.write(s, jnp.arange(6))
        >>> res = eng.search(store, q, SearchRequest(mode="full",
        ...                                          noisy=False))
        >>> bool(jnp.array_equal(votes, res.votes))   # train == serve
        True
        """
        cfg = self.cfg
        enc = cfg.enc
        sl = cfg.mcam.string_len
        if cfg.mode == "avss":
            q, v = quant_lib.quantize_asymmetric(
                q_emb, s_emb, enc.levels, clip_std, 4, rng=rng_range)
        else:
            v, _, rng = quant_lib.fake_quant(
                s_emb, quant_lib.QuantSpec(enc.levels, clip_std), rng_range)
            q, _, _ = quant_lib.fake_quant(
                q_emb, quant_lib.QuantSpec(enc.levels, clip_std), rng)
        s_grid = avss_lib.layout_support_words(
            enc_lib.encode_words_ste(v, enc), sl)          # (N, seg, L, sl)
        if cfg.mode == "avss":
            q_grid = avss_lib.layout_query(q, enc, "avss", sl)
        else:
            q_grid = avss_lib.layout_support_words(
                enc_lib.encode_words_ste(q, enc), sl)
        mm = jnp.abs(q_grid[:, None] - s_grid[None])   # (B, N, seg, L, sl)
        qidx = jnp.arange(q_emb.shape[0],
                          dtype=jnp.uint32)[:, None, None, None]
        votes, dist = avss_lib.votes_from_mismatch(
            mm, qidx, enc.weights_array(), cfg,
            jnp.asarray(cfg.mcam.thresholds()), noisy=noisy,
            noise_stream=_noise_stream(key),
            step_fn=lambda x: mcam_lib.ste_step(x, sa_tau))
        return {"votes": votes, "dist": dist,
                "iterations": self._iterations(q_emb.shape[-1])}

    def episode_scores(self, q_emb: jax.Array, s_emb: jax.Array,
                       s_labels: jax.Array, n_classes: int, *,
                       clip_std: float = 2.5, sa_tau: float = 0.02,
                       key: jax.Array | int | None = None,
                       noisy: bool | None = None,
                       rng_range: tuple[jax.Array, jax.Array] | None = None
                       ) -> jax.Array:
        """Per-class episodic logits (B, n_classes): `episode_votes`
        aggregated by `avss.class_mean_votes` -- the head HAT's CE loss
        trains and the served evaluation reuses (examples/fsl_omniglot.py,
        launch/train.py --hat)."""
        votes = self.episode_votes(
            q_emb, s_emb, clip_std=clip_std, sa_tau=sa_tau, key=key,
            noisy=noisy, rng_range=rng_range)["votes"]
        return avss_lib.class_mean_votes(votes, s_labels, n_classes)

    # -- phase-0 helpers ---------------------------------------------------

    def _grids(self, q_values: jax.Array, s_values: jax.Array,
               s_grid: jax.Array | None = None
               ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
        cfg = self.cfg
        enc = cfg.enc
        sl = cfg.mcam.string_len
        if s_grid is None:                 # read-time layout (raw-array API)
            s_grid = avss_lib.layout_support(s_values, enc, sl)
        q_grid = avss_lib.layout_query(q_values, enc, cfg.mode, sl)
        return q_grid, s_grid, enc.weights_array(), \
            jnp.asarray(cfg.mcam.thresholds())

    def _iterations(self, d: int) -> int:
        cfg = self.cfg
        return avss_lib.search_iterations(d, cfg.enc, cfg.mode,
                                          cfg.mcam.string_len)

    # -- full exact search -------------------------------------------------

    def full(self, q_values: jax.Array, s_values: jax.Array, *,
             s_grid: jax.Array | None = None,
             noise_qidx: jax.Array | None = None) -> dict[str, jax.Array]:
        """Exact noisy MCAM search of every store row.

        q_values: (B, d) ints -- in [0, 4) for AVSS, [0, levels) for SVSS.
        s_values: (N, d) ints in [0, levels).
        s_grid:   optional write-time string grid (MemoryStore.s_grid);
                  when omitted the layout is computed here, read-time.
        noise_qidx: optional (B,) per-query noise coordinates (default
                  arange(B), the batch position). `search_tenants` passes
                  each query's rank within its tenant group so batched and
                  solo noisy searches agree bit-for-bit.
        Returns {votes (B, N), dist (B, N), iterations}.
        """
        cfg = self.cfg
        q_grid, s_grid, weights, thresholds = self._grids(q_values, s_values,
                                                          s_grid)
        if noise_qidx is None:
            noise_qidx = jnp.arange(q_grid.shape[0], dtype=jnp.uint32)
        if self.resolved_backend == "ref":
            fn = partial(avss_lib._search_one_query, weights=weights,
                         cfg=cfg, thresholds=thresholds)
            votes, dist = jax.lax.map(
                lambda args: fn(args[0], s_grid, args[1]),
                (q_grid, noise_qidx.astype(jnp.uint32)),
                batch_size=min(cfg.query_chunk, q_grid.shape[0]))
        else:  # pallas / mxu / fused all use the fused VPU search kernel
            from repro.kernels import ops as kernel_ops
            votes, dist = kernel_ops.mcam_search(
                q_grid, s_grid, weights, cfg, thresholds, qidx=noise_qidx)
        return {"votes": votes, "dist": dist,
                "iterations": self._iterations(q_values.shape[-1])}

    # -- phase-1 shortlist -------------------------------------------------

    def shortlist(self, q_values: jax.Array, s_values: jax.Array, k: int,
                  valid: jax.Array | None = None,
                  proj: jax.Array | None = None,
                  packed: jax.Array | None = None,
                  pack_bits: int | None = None,
                  fused_min_rows: int | None = None
                  ) -> tuple[jax.Array, jax.Array]:
        """Top-k supports by ideal digital AVSS distance.

        Returns (dist (B, k), indices (B, k)), ranked by (distance, index)
        lexicographically ascending -- identical across backends, including
        tie handling (distances are integer-valued, see module docstring).

        valid: optional (N,) bool mask; masked rows get the integer-exact
        SHORTLIST_MASK_PENALTY added to their distance, so they rank after
        every valid row (and keep their relative order, preserving backend
        and sharding bit-parity). Their returned dist includes the penalty.

        proj: optional write-time LUT projection (MemoryStore.proj) for the
        mxu/fused backends; identical to recomputing it from s_values (the
        projection is a deterministic function of the values), just hoisted
        out of the search. The ref backend always recomputes -- it is the
        readable reference, and its distances are bit-identical anyway.

        packed: optional bit-packed projection (MemoryStore.proj_packed);
        the fused kernel then streams the 4-8x smaller int32 operand
        instead of `proj`, bit-identically (kernels/shortlist.py).
        pack_bits: the width `packed` was packed with (MemoryStore
        .pack_bits); required whenever `packed` is given without the
        matching `proj` (the width depends on the packing dtype).

        Dispatch mirrors every other shortlist site: the fused Pallas
        kernel engages on the 'fused' backend, and on any kernel backend
        once N reaches the fused threshold (`fused_min_rows`, overridable
        per call); 'ref' and small N keep the dense matmul + lax.top_k.
        """
        from repro.kernels import ops as kernel_ops
        cfg = self.cfg
        assert cfg.mode == "avss", "shortlists use the AVSS LUT"
        k = min(k, s_values.shape[0])
        backend = self.resolved_backend
        if fused_min_rows is None:
            fused_min_rows = self.fused_min_rows
        if backend == "fused" or (backend != "ref"
                                  and s_values.shape[0] >= fused_min_rows):
            return kernel_ops.lut_shortlist(q_values, s_values, cfg.enc, k,
                                            valid=valid, proj=proj,
                                            packed=packed,
                                            pack_bits=pack_bits)
        if backend == "ref":
            lut = jnp.asarray(enc_lib.avss_sum_lut(cfg.enc), jnp.float32)
            dist = ref_kernels.avss_dist_ref(q_values, s_values, lut)
        else:  # pallas / mxu: LUT matmul kernel
            dist = kernel_ops.avss_ideal_dist(q_values, s_values, cfg.enc,
                                              proj=proj)
        if valid is not None:
            dist = dist + jnp.where(valid, 0.0,
                                    kernel_ops.SHORTLIST_MASK_PENALTY)[None]
        neg, idx = jax.lax.top_k(-dist, k)
        return -neg, idx

    # -- two-phase search --------------------------------------------------

    def two_phase(self, q_values: jax.Array, s_values: jax.Array,
                  k: int = 64, valid: jax.Array | None = None, *,
                  s_grid: jax.Array | None = None,
                  proj: jax.Array | None = None,
                  packed: jax.Array | None = None,
                  pack_bits: int | None = None,
                  fused_min_rows: int | None = None,
                  noise_qidx: jax.Array | None = None
                  ) -> dict[str, jax.Array]:
        """Shortlist + exact noisy rescore (beyond-paper TPU pipeline).

        s_grid / proj: optional write-time layouts (MemoryStore fields);
        omitted -> recomputed here, read-time, with identical results.
        fused_min_rows: phase-1 fused-kernel threshold override (see
        `shortlist`); None defers to the engine's field.
        noise_qidx: optional (B,) per-query noise coordinates for the
        rescore (see `full`); default arange(B).
        Returns {votes (B, k), dist (B, k) ideal shortlist distances
        (masked rows carry the mask penalty), indices (B, k) global support
        rows, iterations}. Votes are bit-identical to `full` for every
        support that makes the shortlist.
        """
        from repro.kernels import ops as kernel_ops
        cfg = self.cfg
        dist, idx = self.shortlist(q_values, s_values, k, valid=valid,
                                   proj=proj, packed=packed,
                                   pack_bits=pack_bits,
                                   fused_min_rows=fused_min_rows)
        q_grid, s_grid, weights, thresholds = self._grids(q_values, s_values,
                                                          s_grid)
        votes = kernel_ops.rescore_shortlist(
            q_grid, s_grid, idx, weights, cfg, thresholds,
            noise_qidx=noise_qidx)
        return {"votes": votes, "dist": dist, "indices": idx,
                "iterations": self._iterations(q_values.shape[-1])}

    # -- sharded two-phase search -------------------------------------------

    def sharded_two_phase(self, q_values: jax.Array, s_values: jax.Array,
                          mesh: Mesh, axes: Sequence[str] = ("data",),
                          k: int = 64, valid: jax.Array | None = None
                          ) -> dict[str, jax.Array]:
        """Two-phase search with the store row-sharded over mesh `axes`.

        Bit-identical to `two_phase` on a single device: each shard
        shortlists its rows (fused Pallas kernel above the engine's
        `fused_min_rows` threshold, dense matmul below), rescores its local
        candidates with GLOBAL support indices feeding the noise counters,
        and the candidate sets are all-gathered and merged by (distance,
        global index). See repro/engine/sharded.py for the exactness
        argument.
        """
        from repro.engine import sharded
        return sharded.sharded_two_phase_search(
            q_values, s_values, self.cfg, mesh, axes=axes, k=k, valid=valid,
            backend=self.resolved_backend,
            fused_min_rows=self.fused_min_rows)
