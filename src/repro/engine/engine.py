"""The RetrievalEngine: one object, three search paths, three backends.

All backends share one semantics contract (kernels/ref.py): for a given
(SearchConfig, query batch, support store) the votes and distances are
bit-identical regardless of backend or sharding. Two facts make this cheap
to guarantee:

* Phase-1 shortlist distances are integer-valued: AVSS LUT entries are small
  integers, query one-hots are 0/1, and every backend accumulates in f32
  (exact for integers < 2**24), so the shortlist distance is the same exact
  float no matter how the reduction is ordered or which unit computes it.
* Phase-2 noise is a counter-based hash of ABSOLUTE (query, string, cell)
  coordinates, so the noisy rescore of a support does not depend on which
  shard or kernel tile evaluates it.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import avss as avss_lib
from repro.core import encodings as enc_lib
from repro.core.avss import SearchConfig
from repro.engine.backends import resolve_backend
from repro.kernels import ref as ref_kernels


@dataclasses.dataclass(frozen=True)
class RetrievalEngine:
    """Dispatches AVSS/SVSS searches to a selected backend.

    cfg:      the end-to-end search configuration (encoding, MCAM physics,
              noise). `cfg.use_kernel` is honoured as a fallback preference.
    backend:  'auto' | 'ref' | 'pallas' | 'mxu' | 'fused'; overrides
              cfg.use_kernel when not 'auto'.
    """

    cfg: SearchConfig
    backend: str = "auto"

    @property
    def resolved_backend(self) -> str:
        return resolve_backend(self.backend, self.cfg.use_kernel)

    # -- phase-0 helpers ---------------------------------------------------

    def _grids(self, q_values: jax.Array, s_values: jax.Array):
        cfg = self.cfg
        enc = cfg.enc
        sl = cfg.mcam.string_len
        s_grid = avss_lib.layout_support(s_values, enc, sl)
        q_grid = avss_lib.layout_query(q_values, enc, cfg.mode, sl)
        return q_grid, s_grid, enc.weights_array(), \
            jnp.asarray(cfg.mcam.thresholds())

    def _iterations(self, d: int) -> int:
        cfg = self.cfg
        return avss_lib.search_iterations(d, cfg.enc, cfg.mode,
                                          cfg.mcam.string_len)

    # -- full exact search -------------------------------------------------

    def full(self, q_values: jax.Array, s_values: jax.Array
             ) -> dict[str, jax.Array]:
        """Exact noisy MCAM search of every store row.

        q_values: (B, d) ints -- in [0, 4) for AVSS, [0, levels) for SVSS.
        s_values: (N, d) ints in [0, levels).
        Returns {votes (B, N), dist (B, N), iterations}.
        """
        cfg = self.cfg
        q_grid, s_grid, weights, thresholds = self._grids(q_values, s_values)
        if self.resolved_backend == "ref":
            fn = partial(avss_lib._search_one_query, weights=weights,
                         cfg=cfg, thresholds=thresholds)
            qidx = jnp.arange(q_grid.shape[0], dtype=jnp.uint32)
            votes, dist = jax.lax.map(
                lambda args: fn(args[0], s_grid, args[1]), (q_grid, qidx),
                batch_size=min(cfg.query_chunk, q_grid.shape[0]))
        else:  # pallas / mxu / fused all use the fused VPU search kernel
            from repro.kernels import ops as kernel_ops
            votes, dist = kernel_ops.mcam_search(
                q_grid, s_grid, weights, cfg, thresholds)
        return {"votes": votes, "dist": dist,
                "iterations": self._iterations(q_values.shape[-1])}

    # -- phase-1 shortlist -------------------------------------------------

    def shortlist(self, q_values: jax.Array, s_values: jax.Array, k: int,
                  valid: jax.Array | None = None
                  ) -> tuple[jax.Array, jax.Array]:
        """Top-k supports by ideal digital AVSS distance.

        Returns (dist (B, k), indices (B, k)), ranked by (distance, index)
        lexicographically ascending -- identical across backends, including
        tie handling (distances are integer-valued, see module docstring).

        valid: optional (N,) bool mask; masked rows get the integer-exact
        SHORTLIST_MASK_PENALTY added to their distance, so they rank after
        every valid row (and keep their relative order, preserving backend
        and sharding bit-parity). Their returned dist includes the penalty.
        """
        from repro.kernels import ops as kernel_ops
        cfg = self.cfg
        assert cfg.mode == "avss", "shortlists use the AVSS LUT"
        k = min(k, s_values.shape[0])
        backend = self.resolved_backend
        if backend == "fused":
            return kernel_ops.lut_shortlist(q_values, s_values, cfg.enc, k,
                                            valid=valid)
        if backend == "ref":
            lut = jnp.asarray(enc_lib.avss_sum_lut(cfg.enc), jnp.float32)
            dist = ref_kernels.avss_dist_ref(q_values, s_values, lut)
        else:  # pallas / mxu: LUT matmul kernel
            dist = kernel_ops.avss_ideal_dist(q_values, s_values, cfg.enc)
        if valid is not None:
            dist = dist + jnp.where(valid, 0.0,
                                    kernel_ops.SHORTLIST_MASK_PENALTY)[None]
        neg, idx = jax.lax.top_k(-dist, k)
        return -neg, idx

    # -- two-phase search --------------------------------------------------

    def two_phase(self, q_values: jax.Array, s_values: jax.Array,
                  k: int = 64, valid: jax.Array | None = None
                  ) -> dict[str, jax.Array]:
        """Shortlist + exact noisy rescore (beyond-paper TPU pipeline).

        Returns {votes (B, k), dist (B, k) ideal shortlist distances
        (masked rows carry the mask penalty), indices (B, k) global support
        rows, iterations}. Votes are bit-identical to `full` for every
        support that makes the shortlist.
        """
        from repro.kernels import ops as kernel_ops
        cfg = self.cfg
        dist, idx = self.shortlist(q_values, s_values, k, valid=valid)
        q_grid, s_grid, weights, thresholds = self._grids(q_values, s_values)
        votes = kernel_ops.rescore_shortlist(
            q_grid, s_grid, idx, weights, cfg, thresholds)
        return {"votes": votes, "dist": dist, "indices": idx,
                "iterations": self._iterations(q_values.shape[-1])}

    # -- sharded two-phase search -------------------------------------------

    def sharded_two_phase(self, q_values: jax.Array, s_values: jax.Array,
                          mesh, axes=("data",), k: int = 64,
                          valid: jax.Array | None = None
                          ) -> dict[str, jax.Array]:
        """Two-phase search with the store row-sharded over mesh `axes`.

        Bit-identical to `two_phase` on a single device: each shard
        shortlists its rows, rescores its local candidates with GLOBAL
        support indices feeding the noise counters, and the candidate sets
        are all-gathered and merged by (distance, global index). See
        repro/engine/sharded.py for the exactness argument.
        """
        from repro.engine import sharded
        return sharded.sharded_two_phase_search(
            q_values, s_values, self.cfg, mesh, axes=axes, k=k, valid=valid)
