"""TenantStore: N per-tenant MemoryStores stacked into one batched store.

The ROADMAP north star is millions of users, but a `MemoryStore` serves
exactly one support set: a process hosting many tenants would pay one jit
cache entry and one device round-trip per tenant. This module is the
MANN-serving analogue of SEE-MCAM's scalable-array argument: stack every
tenant's programmed store along a leading tenant axis so ONE compiled
search program (`RetrievalEngine.search_tenants`) serves them all.

Stacking rules (enforced by `stack`):

* every store is unsharded and shares one SearchConfig and embedding dim
  (the search program is shared, so its static configuration must be);
* ragged capacities are padded to the stack-wide maximum with the SAME
  label -1 / value-0 rows `MemoryStore.shard` pads ragged splits with --
  consistent write-time layouts, masked by the integer-exact
  SHORTLIST_MASK_PENALTY, so pad rows rank after every valid row and
  bit-parity with the solo per-tenant search survives padding;
* per-tenant state that searches need under jit (values / proj /
  proj_packed / s_grid / labels / size / lo / hi / the router
  sketch_sums / sketch_counts) becomes batched data leaves; per-tenant static metadata (each store's MemoryConfig and
  calibration flag) rides along as aux data, so `tenant(i)` round-trips
  the EXACT original store.

Lifecycle mirrors the solo store: `stack(stores)` -> serve via
`engine.search_tenants` -> `write_at(tenant_id, vectors, labels)` per-
tenant ring writes (functional, shapes preserved, so the compiled search
is never retraced by a write). See docs/architecture.md ("Multi-tenant
serving") and launch/serve.py's `TenantServer` for the coalescing shell.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.memory import MemoryConfig
from repro.engine import router as router_lib
from repro.engine.store import MemoryStore, _layout, _quantize
from repro.kernels import ops as kernel_ops


def tenant_query_rank(tenant_ids: jax.Array) -> jax.Array:
    """(B,) rank of each query within its tenant group, in batch order.

    This is the noise coordinate `search_tenants` feeds the counter-based
    hardware noise: query b gets the batch position it WOULD have in a
    solo per-tenant `engine.search` call over the same tenant's queries
    (in batch order) -- which is exactly what makes the coalesced noisy
    search bit-identical to the per-tenant solo one. O(B^2) one-hot
    cumulation; serving batches are small.

    >>> import jax.numpy as jnp
    >>> tenant_query_rank(jnp.array([2, 0, 2, 2, 0])).tolist()
    [0, 0, 1, 2, 1]
    """
    t = jnp.asarray(tenant_ids)
    same = t[:, None] == t[None, :]                       # (B, B)
    return jnp.tril(same, k=-1).sum(axis=1).astype(jnp.uint32)


@partial(jax.tree_util.register_dataclass,
         data_fields=["values", "proj", "proj_packed", "s_grid", "labels",
                      "size", "lo", "hi", "sketch_sums", "sketch_counts"],
         meta_fields=["cfgs", "calibrated"])
@dataclasses.dataclass(frozen=True)
class TenantStore:
    """N per-tenant MemoryStores as ONE batched pytree (module docstring).

    Data leaves carry a leading tenant axis over the solo store's layout:
    values (T, Np, d), proj (T, Np, 4d), proj_packed (T, Np, w),
    s_grid (T, Np, seg, L, sl), labels (T, Np), size/lo/hi (T,),
    sketch_sums (T, 1, R, d) / sketch_counts (T, 1, R) (each tenant's
    unpartitioned router sketch, kept write-consistent by `write_at`) --
    with Np the stack-wide padded capacity. `cfgs` / `calibrated` keep each
    tenant's ORIGINAL static metadata so `tenant(i)` is an exact inverse
    of `stack`.

    >>> import jax.numpy as jnp
    >>> from repro.core.avss import SearchConfig
    >>> cfg = SearchConfig("mtmc", cl=4, mode="avss", use_kernel="ref")
    >>> a = MemoryStore.from_quantized(          # capacity 3
    ...     jnp.array([[0, 3], [5, 5], [9, 7]]), jnp.array([1, 2, 3]), cfg)
    >>> b = MemoryStore.from_quantized(          # ragged: capacity 1
    ...     jnp.array([[4, 4]]), jnp.array([7]), cfg)
    >>> ts = TenantStore.stack([a, b])
    >>> ts.n_tenants, ts.n_pad, ts.capacities   # padded to max capacity
    (2, 3, (3, 1))
    >>> ts.labels.tolist()                       # label -1 pad rows
    [[1, 2, 3], [7, -1, -1]]
    >>> bool(jnp.array_equal(ts.tenant(1).values, b.values))  # round-trip
    True
    """

    values: jax.Array
    proj: jax.Array
    proj_packed: jax.Array
    s_grid: jax.Array
    labels: jax.Array
    size: jax.Array
    lo: jax.Array
    hi: jax.Array
    sketch_sums: jax.Array
    sketch_counts: jax.Array
    cfgs: tuple[MemoryConfig, ...]
    calibrated: tuple[bool, ...]

    # -- construction --------------------------------------------------------

    @classmethod
    def stack(cls, stores: Sequence[MemoryStore]) -> "TenantStore":
        """Stack per-tenant stores along a new leading tenant axis.

        Every store must be unsharded and share one SearchConfig and dim;
        ragged capacities are padded to the maximum with label -1 rows
        exactly like `MemoryStore.shard` pads ragged splits (value-0 rows
        with CONSISTENT write-time layouts, so pads are indistinguishable
        from never-written slots and rank last under the mask penalty).
        """
        if not stores:
            raise ValueError("TenantStore.stack: need at least one store")
        first = stores[0]
        for i, s in enumerate(stores):
            if s.mesh is not None:
                raise ValueError(
                    f"TenantStore.stack: store {i} is sharded; stack "
                    f"unsharded stores (shard-of-stacks is not supported)")
            if s.cfg.search != first.cfg.search or s.dim != first.dim:
                raise ValueError(
                    f"TenantStore.stack: store {i} disagrees with store 0 "
                    f"on SearchConfig/dim; the stacked search program is "
                    f"shared, so its static configuration must be")
        n_pad = max(s.cfg.capacity for s in stores)
        padded = [s._unpad()._pad_rows(n_pad - s.cfg.capacity)
                  for s in stores]
        stk = lambda leaf: jnp.stack([getattr(s, leaf) for s in padded])
        return cls(values=stk("values"), proj=stk("proj"),
                   proj_packed=stk("proj_packed"), s_grid=stk("s_grid"),
                   labels=stk("labels"), size=stk("size"), lo=stk("lo"),
                   hi=stk("hi"), sketch_sums=stk("sketch_sums"),
                   sketch_counts=stk("sketch_counts"),
                   cfgs=tuple(s.cfg for s in stores),
                   calibrated=tuple(s.calibrated for s in stores))

    # -- derived properties --------------------------------------------------

    @property
    def n_tenants(self) -> int:
        return self.values.shape[0]

    @property
    def n_pad(self) -> int:
        """Padded per-tenant capacity (the stack-wide maximum)."""
        return self.values.shape[1]

    @property
    def capacities(self) -> tuple[int, ...]:
        """Each tenant's LOGICAL (pre-padding) capacity."""
        return tuple(c.capacity for c in self.cfgs)

    @property
    def cfg(self) -> MemoryConfig:
        """The shared static config of the per-query search views: tenant
        0's MemoryConfig at the padded capacity (all stacked stores agree
        on everything a search reads from it -- `stack` enforces it)."""
        return dataclasses.replace(self.cfgs[0], capacity=self.n_pad)

    # -- solo views ----------------------------------------------------------

    def tenant(self, i: int) -> MemoryStore:
        """Tenant i's solo MemoryStore, exactly as it was stacked: pads
        dropped, original MemoryConfig and calibration flag restored --
        `stack(stores).tenant(i)` equals `stores[i]` leaf-for-leaf."""
        cap = self.cfgs[i].capacity
        return MemoryStore(
            values=self.values[i, :cap], proj=self.proj[i, :cap],
            proj_packed=self.proj_packed[i, :cap],
            s_grid=self.s_grid[i, :cap], labels=self.labels[i, :cap],
            size=self.size[i], lo=self.lo[i], hi=self.hi[i],
            sketch_sums=self.sketch_sums[i],
            sketch_counts=self.sketch_counts[i],
            cfg=self.cfgs[i], calibrated=self.calibrated[i])

    def query_view(self, tenant_ids: jax.Array) -> MemoryStore:
        """Per-QUERY store view: every leaf gathered at `tenant_ids`, so
        leaf b is the owning tenant's store row block. The result is a
        MemoryStore pytree with one extra leading batch axis -- exactly
        what `RetrievalEngine.search_tenants` vmaps the single-query
        search over (in_axes=0 on every data leaf, static cfg shared)."""
        take = lambda a: a[tenant_ids]
        return MemoryStore(
            values=take(self.values), proj=take(self.proj),
            proj_packed=(None if self.proj_packed is None
                         else take(self.proj_packed)),
            s_grid=take(self.s_grid), labels=take(self.labels),
            size=take(self.size), lo=take(self.lo), hi=take(self.hi),
            sketch_sums=take(self.sketch_sums),
            sketch_counts=take(self.sketch_counts),
            cfg=self.cfg, calibrated=True)

    # -- programming ---------------------------------------------------------

    def quantize_queries(self, queries: jax.Array,
                         tenant_ids: jax.Array) -> jax.Array:
        """Float embeddings -> quantized query words, each query against
        the OWNING tenant's calibrated (lo, hi) range -- value-identical
        to `tenant(t).quantize_queries(q)` per query. Integer queries pass
        through untouched. Float queries require EVERY tenant calibrated
        (tenant_ids is traced data, so the guard cannot be per-tenant)."""
        if jnp.issubdtype(queries.dtype, jnp.integer):
            return queries
        if not all(self.calibrated):
            raise ValueError(
                "TenantStore.quantize_queries: float queries on a stack "
                "with never-calibrated tenants "
                f"{[i for i, c in enumerate(self.calibrated) if not c]} "
                "would quantize against the default (lo=0, hi=1) range "
                "and return garbage words; calibrate every store before "
                "stacking, or pass pre-quantized integer queries.")
        cfg = self.cfgs[0].search
        levels = 4 if cfg.mode == "avss" else cfg.enc.levels
        return _quantize(queries, levels, self.lo[tenant_ids][:, None],
                         self.hi[tenant_ids][:, None])

    def write_at(self, tenant_id: int | jax.Array, vectors: jax.Array,
                 labels: jax.Array) -> "TenantStore":
        """Program a batch into ONE tenant's ring (functional update).

        The solo `MemoryStore.write` contract per tenant: quantize against
        the tenant's calibrated range, scatter into its ring at
        `(size % capacity + arange(n)) % capacity` (the LOGICAL capacity,
        so pad rows are never written), materialise proj/proj_packed/
        s_grid write-time. Every leaf keeps its shape, so a compiled
        `search_tenants` program is NEVER retraced by a write --
        `tenant(t)` afterwards equals `stores[t].write(vectors, labels)`
        bit-for-bit. `tenant_id` may be a traced array (one jitted write
        program serves every tenant); the lifecycle guards then need every
        tenant calibrated and `n <= min(capacities)`.
        """
        n = vectors.shape[0]
        if n == 0:
            return self
        caps = self.capacities
        try:
            static_t: int | None = int(tenant_id)
        except (TypeError, jax.errors.JAXTypeError):
            static_t = None                        # traced tenant id
        if static_t is not None:
            if not self.calibrated[static_t]:
                raise ValueError(
                    f"TenantStore.write_at: tenant {static_t} was stacked "
                    f"never-calibrated; calibrate before stacking (already-"
                    f"quantized supports go through "
                    f"MemoryStore.from_quantized).")
            assert n <= caps[static_t], \
                f"write batch ({n}) exceeds tenant capacity " \
                f"({caps[static_t]})"
        else:
            if not all(self.calibrated):
                raise ValueError(
                    "TenantStore.write_at: traced tenant_id on a stack "
                    "with never-calibrated tenants; calibrate every store "
                    "before stacking.")
            assert n <= min(caps), \
                f"write batch ({n}) exceeds the smallest tenant " \
                f"capacity ({min(caps)})"
        t = jnp.asarray(tenant_id, jnp.int32)
        ring = jnp.asarray(caps, jnp.int32)[t]
        enc = self.cfgs[0].search.enc
        v = _quantize(vectors, enc.levels, self.lo[t], self.hi[t])
        idx = (self.size[t] % ring
               + jnp.arange(n, dtype=jnp.int32)) % ring
        proj = kernel_ops.support_projection(v, enc)
        lab = labels.astype(jnp.int32)
        # the tenant's router sketch follows MemoryStore._program's
        # incremental S=1 path exactly (same helper, same int32 delta over
        # the distinct ring slots), so tenant(t) stays bit-identical to
        # the solo store's write
        n_buckets = self.sketch_sums.shape[2]
        ds_new, dc_new = router_lib.bucket_sums(v, lab, n_buckets)
        ds_old, dc_old = router_lib.bucket_sums(self.values[t, idx],
                                                self.labels[t, idx],
                                                n_buckets)
        return dataclasses.replace(
            self,
            values=self.values.at[t, idx].set(v),
            proj=self.proj.at[t, idx].set(proj.astype(self.proj.dtype)),
            proj_packed=self.proj_packed.at[t, idx].set(
                kernel_ops.pack_projection(proj, enc)),
            s_grid=self.s_grid.at[t, idx].set(_layout(v, self.cfgs[0])),
            labels=self.labels.at[t, idx].set(lab),
            sketch_sums=self.sketch_sums.at[t, 0].add(ds_new - ds_old),
            sketch_counts=self.sketch_counts.at[t, 0].add(dc_new - dc_old),
            size=self.size.at[t].add(n),
        )
