"""MemoryStore: the programmed MCAM memory as an immutable registered pytree.

The paper's premise is that support vectors are *programmed once* into MCAM
strings and searched many times: MTMC encoding happens at write time (Sec.
3.1) and AVSS reads the fixed layout (Sec. 3.2). The store mirrors that --
`write` materialises everything a search ever needs:

  values   (N, d)  int32   quantized support values (ring buffer)
  proj     (N, 4d) bf16    AVSS LUT projection (phase-1 MXU shortlists)
  s_grid   (N, seg, L, sl) int8  string-grid layout (full search / rescore)
  labels   (N,)    int32   class / token labels; -1 marks an empty slot
                           (never written, or a ragged-shard pad row)
  size     ()      int32   total writes so far (monotonic; ring position)
  lo, hi   ()      f32     calibrated quantization range

so searches -- including the decode loop `serve --retrieval` jits -- run
against write-time constants instead of re-running `layout_support` /
`support_projection` per call. Sharding is a store property:
`shard(mesh, axes)` row-shards the store (padding ragged splits with
label -1 rows that the integer-exact mask penalty ranks last) and records
(mesh, axes) as static metadata, making `RetrievalEngine.search` dispatch
shard-aware with no caller plumbing.

All update methods are functional (returning a new store); the store is a
pytree, so it passes through jit / shard_map / eval_shape like any array
tree, with (cfg, mesh, axes) as static aux data.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import avss as avss_lib
from repro.core.memory import MemoryConfig
from repro.kernels import ops as kernel_ops


def _quantize(x: jax.Array, levels: int, lo, hi) -> jax.Array:
    scale = (levels - 1) / (hi - lo)
    q = jnp.round((jnp.clip(x, lo, hi) - lo) * scale)
    return jnp.clip(q, 0, levels - 1).astype(jnp.int32)


@partial(jax.tree_util.register_dataclass,
         data_fields=["values", "proj", "s_grid", "labels", "size",
                      "lo", "hi"],
         meta_fields=["cfg", "mesh", "axes"])
@dataclasses.dataclass(frozen=True)
class MemoryStore:
    """Immutable programmed MCAM store (see module docstring)."""

    values: jax.Array
    proj: jax.Array
    s_grid: jax.Array
    labels: jax.Array
    size: jax.Array
    lo: jax.Array
    hi: jax.Array
    cfg: MemoryConfig
    mesh: object = None
    axes: tuple = ()

    # -- construction --------------------------------------------------------

    @classmethod
    def create(cls, cfg: MemoryConfig) -> "MemoryStore":
        """Empty store: every slot reads as value 0 with label -1, and the
        write-time artifacts (proj, s_grid) are CONSISTENT with value 0 --
        exactly what a later `write` of value 0 would program. This keeps
        empty slots and written slots indistinguishable to phase 1 except
        through the label mask, which is what preserves bit-parity between
        ragged-pad rows, empty slots, and the unsharded search."""
        enc = cfg.search.enc
        zeros = jnp.zeros((cfg.capacity, cfg.dim), jnp.int32)
        return cls(
            values=zeros,
            proj=kernel_ops.support_projection(zeros, enc),
            s_grid=_layout(zeros, cfg),
            labels=jnp.full((cfg.capacity,), -1, jnp.int32),
            size=jnp.zeros((), jnp.int32),
            lo=jnp.zeros((), jnp.float32),
            hi=jnp.ones((), jnp.float32),
            cfg=cfg,
        )

    @classmethod
    def from_quantized(cls, values: jax.Array, labels: jax.Array,
                       search_cfg) -> "MemoryStore":
        """Program an already-quantized support set (ints in [0, levels))
        as a full store of capacity == len(values). The episodic evaluation
        path (examples/fsl_omniglot.py) quantizes asymmetrically per
        episode and lands here. Every slot is written, so the layouts are
        built directly (no empty-slot init pass)."""
        n, d = values.shape
        cfg = MemoryConfig(capacity=n, dim=d, search=search_cfg)
        v = values.astype(jnp.int32)
        return cls(
            values=v,
            proj=kernel_ops.support_projection(v, cfg.search.enc),
            s_grid=_layout(v, cfg),
            labels=labels.astype(jnp.int32),
            size=jnp.asarray(n, jnp.int32),
            lo=jnp.zeros((), jnp.float32),
            hi=jnp.ones((), jnp.float32),
            cfg=cfg,
        )

    @classmethod
    def from_state(cls, state: dict, cfg: MemoryConfig) -> "MemoryStore":
        """Adopt a legacy `core.memory` state dict (pre-redesign contract).
        Dicts from old checkpoints may lack the write-time `s_grid`; it is
        derived from `values` (deterministic, so results stay identical)."""
        s_grid = state.get("s_grid")
        if s_grid is None:
            s_grid = _layout(state["values"], cfg)
        return cls(values=state["values"], proj=state["proj"],
                   s_grid=s_grid, labels=state["labels"],
                   size=state["size"], lo=state["lo"], hi=state["hi"],
                   cfg=cfg)

    def to_state(self) -> dict:
        """Legacy state-dict view (the pre-redesign `core.memory` contract,
        plus the write-time `s_grid`)."""
        return {"values": self.values, "proj": self.proj,
                "s_grid": self.s_grid, "labels": self.labels,
                "size": self.size, "lo": self.lo, "hi": self.hi}

    # -- derived properties --------------------------------------------------

    @property
    def capacity(self) -> int:
        """Physical rows, including any ragged-shard pad rows."""
        return self.values.shape[0]

    @property
    def dim(self) -> int:
        return self.values.shape[1]

    @property
    def valid(self) -> jax.Array:
        """(N,) bool: slots holding a written support (pad rows and
        never-written slots carry label -1 and are masked out of phase 1
        via the integer-exact SHORTLIST_MASK_PENALTY)."""
        return self.labels >= 0

    # -- programming ---------------------------------------------------------

    def calibrate(self, vectors: jax.Array) -> "MemoryStore":
        """Set the quantization range from a sample of embeddings (std
        clipping clamped to the data extent, paper Sec. 3.3). Must run
        before the first write."""
        mu, sd = vectors.mean(), vectors.std() + 1e-8
        lo = jnp.maximum(mu - self.cfg.clip_std * sd, vectors.min())
        hi = jnp.minimum(mu + self.cfg.clip_std * sd, vectors.max() + 1e-8)
        return dataclasses.replace(self, lo=lo, hi=hi)

    def write(self, vectors: jax.Array, labels: jax.Array) -> "MemoryStore":
        """Program a batch of float support embeddings (ring buffer).

        Write-time MCAM programming: quantization, the MTMC/AVSS LUT
        projection AND the string-grid layout are all materialised here,
        once, so every later search jits against constants. Batches larger
        than the capacity are rejected (a single batch would overwrite
        itself mid-write)."""
        n = vectors.shape[0]
        ring = self.cfg.capacity
        assert n <= ring, f"write batch ({n}) exceeds capacity ({ring})"
        v = _quantize(vectors, self.cfg.search.enc.levels, self.lo, self.hi)
        start = self.size % ring
        idx = (start + jnp.arange(n)) % ring
        return self._program(idx, v, labels, n)

    def _program(self, idx, v, labels, n) -> "MemoryStore":
        enc = self.cfg.search.enc
        return dataclasses.replace(
            self,
            values=self.values.at[idx].set(v),
            proj=self.proj.at[idx].set(kernel_ops.support_projection(v, enc)),
            s_grid=self.s_grid.at[idx].set(_layout(v, self.cfg)),
            labels=self.labels.at[idx].set(labels.astype(jnp.int32)),
            size=self.size + n,
        )

    def quantize_queries(self, queries: jax.Array) -> jax.Array:
        """Float embeddings -> quantized query words ([0, 4) for AVSS,
        [0, levels) for SVSS). Integer queries pass through untouched
        (already quantized, e.g. the episodic evaluation path)."""
        if jnp.issubdtype(queries.dtype, jnp.integer):
            return queries
        cfg = self.cfg.search
        levels = 4 if cfg.mode == "avss" else cfg.enc.levels
        return _quantize(queries, levels, self.lo, self.hi)

    # -- sharding ------------------------------------------------------------

    def shard(self, mesh, axes=("data",)) -> "MemoryStore":
        """Row-shard the store over mesh `axes` and record the sharding as
        a store property (RetrievalEngine.search dispatches on it).

        Ragged splits are supported: when the row count does not divide the
        shard count, the store is padded with label -1 rows programmed to
        value 0 -- indistinguishable from never-written slots, so the mask
        penalty ranks them after every valid row and top-k results stay
        bit-identical to the unsharded search for k <= the unpadded row
        count."""
        axes = tuple(axes)
        n_shards = int(np.prod([mesh.shape[a] for a in axes]))
        store = self._pad_rows((-self.capacity) % n_shards)
        row = NamedSharding(mesh, P(axes))
        rep = NamedSharding(mesh, P())
        return dataclasses.replace(
            store,
            values=jax.device_put(store.values, row),
            proj=jax.device_put(store.proj, row),
            s_grid=jax.device_put(store.s_grid, row),
            labels=jax.device_put(store.labels, row),
            size=jax.device_put(store.size, rep),
            lo=jax.device_put(store.lo, rep),
            hi=jax.device_put(store.hi, rep),
            mesh=mesh, axes=axes,
        )

    def _pad_rows(self, pad: int) -> "MemoryStore":
        if pad == 0:
            return self
        enc = self.cfg.search.enc
        zeros = jnp.zeros((pad, self.dim), jnp.int32)
        cat = lambda a, b: jnp.concatenate([a, b], axis=0)
        return dataclasses.replace(
            self,
            values=cat(self.values, zeros),
            proj=cat(self.proj, kernel_ops.support_projection(zeros, enc)),
            s_grid=cat(self.s_grid, _layout(zeros, self.cfg)),
            labels=cat(self.labels, jnp.full((pad,), -1, jnp.int32)),
        )


def _layout(values: jax.Array, cfg: MemoryConfig) -> jax.Array:
    """Write-time string-grid layout: (n, d) -> (n, seg, L, sl) int8 codes
    (code words are in [0, 3]; int8 is what the kernels consume)."""
    grid = avss_lib.layout_support(values, cfg.search.enc,
                                   cfg.search.mcam.string_len)
    return grid.astype(jnp.int8)
