"""MemoryStore: the programmed MCAM memory as an immutable registered pytree.

The paper's premise is that support vectors are *programmed once* into MCAM
strings and searched many times: MTMC encoding happens at write time (Sec.
3.1) and AVSS reads the fixed layout (Sec. 3.2). The store mirrors that --
`write` materialises everything a search ever needs:

  values   (N, d)  int32   quantized support values (ring buffer)
  proj     (N, 4d) bf16    AVSS LUT projection (phase-1 MXU shortlists)
  proj_packed (N, ceil(4d/wpi)) int32  the same projection bit-packed
                           (kernels/ops.pack_projection, wpi = 32/bits LUT
                           words per int32) -- the fused shortlist streams
                           this 4-8x smaller operand instead of `proj`
  s_grid   (N, seg, L, sl) int8  string-grid layout (full search / rescore)
  labels   (N,)    int32   class / token labels; -1 marks an empty slot
                           (never written, or a ragged-shard pad row)
  size     ()      int32   total writes so far (monotonic; ring position)
  lo, hi   ()      f32     calibrated quantization range
  sketch_sums   (S, R, d) int32  phase-0 router sketch: per row-shard,
  sketch_counts (S, R)    int32  per-class-bucket sums/counts of valid
                           rows (engine/router.py). Maintained by both
                           write paths (integer-exact, scatter-free) and
                           rebuilt by `shard`; S=1 on unsharded stores.

so searches -- including the decode loop `serve --retrieval` jits -- run
against write-time constants instead of re-running `layout_support` /
`support_projection` per call. Sharding is a store property:
`shard(mesh, axes)` row-shards the store (padding ragged splits with
label -1 rows that the integer-exact mask penalty ranks last) and records
(mesh, axes) as static metadata, making `RetrievalEngine.search` dispatch
shard-aware with no caller plumbing. Re-sharding always starts from the
LOGICAL `cfg.capacity` rows, so `shard` is idempotent (pads never pad).
`shard` also partitions WITHOUT a mesh (`shard(n_shards=S)`): the store
keeps its global arrays but records S contiguous row blocks in the
router sketch, which is what `SearchRequest.nprobe` routes over; with
`residency="host"` the blocks additionally live in host memory and are
paged onto the device by `engine/pager.ShardPager` (beyond-HBM serving).

Writes on a MULTI-shard store stay shard-LOCAL (the paper's economics:
NAND programming is the cheap in-place operation). `write` dispatches to a
shard_map write-through in which each shard computes which slice of the
(replicated) incoming batch lands in its own ring segment and programs
values/proj/proj_packed/s_grid/labels in place -- the compiled HLO
contains no cross-device collectives and no scatter (tests/test_store.py),
and the result is bit-identical to the unsharded scatter path, including
ragged pads and ring wraparound across shard boundaries. With 1 shard (or
no mesh) the write-through's collective-free advantage cannot exist and
its per-row ring inversion just costs VPU time (7.7x slower in
bench_engine_sharded), so `write` routes single-shard stores through the
plain scatter path -- same bits, fast path.

All update methods are functional (returning a new store); the store is a
pytree, so it passes through jit / shard_map / eval_shape like any array
tree, with (cfg, mesh, axes) as static aux data.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import avss as avss_lib
from repro.core import quantization as quant_lib
from repro.core.avss import SearchConfig
from repro.core.memory import MemoryConfig
from repro.engine import router as router_lib
from repro.kernels import ops as kernel_ops

#: array leaves of the store pytree (register_dataclass data_fields; also
#: the per-leaf set `shard(residency="host")` moves to host memory).
_DATA_FIELDS = ["values", "proj", "proj_packed", "s_grid", "labels",
                "size", "lo", "hi", "sketch_sums", "sketch_counts"]


def _host_device() -> jax.Device | None:
    """The host (CPU) device for `residency="host"` placement, or None
    when jax exposes no CPU backend."""
    try:
        return jax.local_devices(backend="cpu")[0]
    except RuntimeError:
        return None


def _quantize(x: jax.Array, levels: int, lo: jax.Array,
              hi: jax.Array) -> jax.Array:
    # the SAME affine quantizer hardware-aware training fake-quants with
    # (there with an STE round) -- one leg of the train/serve parity
    return quant_lib.affine_quantize(x, levels, lo, hi).astype(jnp.int32)


@partial(jax.tree_util.register_dataclass,
         data_fields=_DATA_FIELDS,
         meta_fields=["cfg", "mesh", "axes", "calibrated", "residency"])
@dataclasses.dataclass(frozen=True)
class MemoryStore:
    """Immutable programmed MCAM store (see module docstring).

    `calibrated` (static metadata) records whether `calibrate` has set the
    quantization range: embeddings quantized against the default (lo=0,
    hi=1) range are garbage words, so `write` refuses ANY input on a
    never-calibrated store (it always quantizes; already-quantized supports
    go through `from_quantized`), and `quantize_queries` refuses float
    queries (integer queries are already words and pass through).

    Lifecycle: create -> calibrate -> write (-> shard), searched through
    `RetrievalEngine.search`:

    >>> import jax.numpy as jnp
    >>> from repro.core.avss import SearchConfig
    >>> from repro.core.memory import MemoryConfig
    >>> from repro.engine import (MemoryStore, RetrievalEngine,
    ...                           SearchRequest)
    >>> cfg = MemoryConfig(capacity=8, dim=4,
    ...                    search=SearchConfig("mtmc", cl=4, mode="avss",
    ...                                        use_kernel="ref"))
    >>> vecs = jnp.linspace(-1.0, 1.0, 12).reshape(3, 4)
    >>> store = MemoryStore.create(cfg).calibrate(vecs)
    >>> store = store.write(vecs, jnp.array([3, 1, 4]))
    >>> int(store.size), store.capacity, int(store.valid.sum())
    (3, 8, 3)
    >>> res = RetrievalEngine(cfg.search).search(
    ...     store, vecs, SearchRequest(mode="two_phase", k=2))
    >>> res.predict().tolist()             # each vector retrieves itself
    [3, 1, 4]
    """

    values: jax.Array
    proj: jax.Array
    proj_packed: jax.Array
    s_grid: jax.Array
    labels: jax.Array
    size: jax.Array
    lo: jax.Array
    hi: jax.Array
    sketch_sums: jax.Array
    sketch_counts: jax.Array
    cfg: MemoryConfig
    mesh: Mesh | None = None
    axes: tuple[str, ...] = ()
    calibrated: bool = False
    residency: str = "device"

    # -- construction --------------------------------------------------------

    @classmethod
    def create(cls, cfg: MemoryConfig) -> "MemoryStore":
        """Empty store: every slot reads as value 0 with label -1, and the
        write-time artifacts (proj, s_grid) are CONSISTENT with value 0 --
        exactly what a later `write` of value 0 would program. This keeps
        empty slots and written slots indistinguishable to phase 1 except
        through the label mask, which is what preserves bit-parity between
        ragged-pad rows, empty slots, and the unsharded search."""
        enc = cfg.search.enc
        zeros = jnp.zeros((cfg.capacity, cfg.dim), jnp.int32)
        proj = kernel_ops.support_projection(zeros, enc)
        labels = jnp.full((cfg.capacity,), -1, jnp.int32)
        sk_sums, sk_counts = router_lib.build_sketch(zeros, labels, 1)
        return cls(
            values=zeros,
            proj=proj,
            proj_packed=kernel_ops.pack_projection(proj, enc),
            s_grid=_layout(zeros, cfg),
            labels=labels,
            size=jnp.zeros((), jnp.int32),
            lo=jnp.zeros((), jnp.float32),
            hi=jnp.ones((), jnp.float32),
            sketch_sums=sk_sums,
            sketch_counts=sk_counts,
            cfg=cfg,
        )

    @classmethod
    def from_quantized(cls, values: jax.Array, labels: jax.Array,
                       search_cfg: SearchConfig) -> "MemoryStore":
        """Program an already-quantized support set (ints in [0, levels))
        as a full store of capacity == len(values). The episodic evaluation
        path (examples/fsl_omniglot.py) quantizes asymmetrically per
        episode and lands here. Every slot is written, so the layouts are
        built directly (no empty-slot init pass)."""
        n, d = values.shape
        cfg = MemoryConfig(capacity=n, dim=d, search=search_cfg)
        v = values.astype(jnp.int32)
        lab = labels.astype(jnp.int32)
        proj = kernel_ops.support_projection(v, cfg.search.enc)
        sk_sums, sk_counts = router_lib.build_sketch(v, lab, 1)
        return cls(
            values=v,
            proj=proj,
            proj_packed=kernel_ops.pack_projection(proj, cfg.search.enc),
            s_grid=_layout(v, cfg),
            labels=lab,
            size=jnp.asarray(n, jnp.int32),
            lo=jnp.zeros((), jnp.float32),
            hi=jnp.ones((), jnp.float32),
            sketch_sums=sk_sums,
            sketch_counts=sk_counts,
            cfg=cfg,
        )

    @classmethod
    def from_episode(cls, s_emb: jax.Array, q_emb: jax.Array,
                     labels: jax.Array, search_cfg: SearchConfig,
                     clip_std: float = 2.5,
                     capacity: int | None = None) -> "MemoryStore":
        """Program an episode's FLOAT support embeddings the way the
        hardware-aware trainer quantized them: calibrated on the SAME
        support+query sample statistics `quantize_asymmetric` saw. This is
        the one train->write->serve recipe -- searches on the returned
        store are bit-identical to the in-training episodic forward
        (`RetrievalEngine.episode_votes`; tests/test_train_serve_parity.py)
        -- shared by `launch/train.py --hat`, examples/fsl_omniglot.py and
        the parity tests so the calibration convention cannot drift."""
        cfg = MemoryConfig(capacity=capacity or s_emb.shape[0],
                           dim=s_emb.shape[1], search=search_cfg,
                           clip_std=clip_std)
        sample = jnp.concatenate([s_emb.ravel(), q_emb.ravel()])
        return cls.create(cfg).calibrate(sample).write(
            s_emb, labels.astype(jnp.int32))

    @classmethod
    def from_state(cls, state: dict[str, jax.Array],
                   cfg: MemoryConfig) -> "MemoryStore":
        """Adopt a legacy `core.memory` state dict (pre-redesign contract).
        Dicts from old checkpoints may lack the write-time `s_grid`; it is
        derived from `values` (deterministic, so results stay identical)."""
        s_grid = state.get("s_grid")
        if s_grid is None:
            s_grid = _layout(state["values"], cfg)
        packed = state.get("proj_packed")
        if packed is None:
            packed = kernel_ops.pack_projection(state["proj"],
                                                cfg.search.enc)
        # legacy dicts carry no calibration flag; adopt their lo/hi as-is
        # (the pre-redesign API managed calibration itself) so the shims in
        # core/memory.py stay bit-identical. The router sketch is a
        # deterministic integer function of (values, labels), so rebuilding
        # it here (state dicts never carry it) is bit-exact.
        sk_sums, sk_counts = router_lib.build_sketch(
            state["values"], state["labels"], 1)
        return cls(values=state["values"], proj=state["proj"],
                   proj_packed=packed,
                   s_grid=s_grid, labels=state["labels"],
                   size=state["size"], lo=state["lo"], hi=state["hi"],
                   sketch_sums=sk_sums, sketch_counts=sk_counts,
                   cfg=cfg, calibrated=True)

    def to_state(self) -> dict[str, jax.Array]:
        """Legacy state-dict view (the pre-redesign `core.memory` contract,
        plus the write-time `s_grid`)."""
        return {"values": self.values, "proj": self.proj,
                "s_grid": self.s_grid, "labels": self.labels,
                "size": self.size, "lo": self.lo, "hi": self.hi}

    # -- persistence ---------------------------------------------------------

    def save(self, directory: str, step: int = 0) -> None:
        """Persist the programmed store through `repro.checkpoint.ckpt`
        (atomic, sharded, manifest-last): values, labels, the write-time
        proj/s_grid layouts, the calibrated quant range and the ring size
        -- everything a separate serving process needs to `restore` and
        search bit-identically. A sharded store writes its addressable
        shards; restore rebuilds the global arrays (re-`shard` after)."""
        from repro.checkpoint import ckpt
        ckpt.save(directory, step, self._unpad().to_state())

    @classmethod
    def restore(cls, directory: str, cfg: MemoryConfig,
                step: int | None = None) -> "MemoryStore":
        """Load a store previously written by `save`. The result is
        unsharded (call `.shard(mesh, axes)` to place it) and marked
        calibrated -- the persisted (lo, hi) range IS the calibration, so
        searches on the restored store are bit-identical to the writer's
        (round-trip asserted in tests/test_checkpoint.py)."""
        from repro.checkpoint import ckpt
        target = jax.eval_shape(lambda: cls.create(cfg).to_state())
        return cls.from_state(ckpt.restore(directory, target, step=step),
                              cfg)

    # -- derived properties --------------------------------------------------

    @property
    def capacity(self) -> int:
        """Physical rows, including any ragged-shard pad rows."""
        return self.values.shape[0]

    @property
    def dim(self) -> int:
        return self.values.shape[1]

    @property
    def n_shards(self) -> int:
        """Number of row shards: mesh-derived when the store is
        device-sharded, else the router sketch's partition count (logical
        `shard(n_shards=S)` blocks; 1 for an unpartitioned store)."""
        if self.mesh is not None:
            return int(np.prod([self.mesh.shape[a] for a in self.axes]))
        return int(self.sketch_sums.shape[0])

    @property
    def pack_bits(self) -> int:
        """Field width (4/8/16/32) of `proj_packed`, fixed at PACK time by
        the encoding and the stored `proj` dtype. This is the one
        authoritative spelling: consumers must unpack with the width the
        operand was packed with, never re-derive it from a default dtype
        (a bf16-vs-f32 projection changes the width for large-LUT
        encodings -- see ops.projection_pack_bits)."""
        return kernel_ops.projection_pack_bits(self.cfg.search.enc,
                                               self.proj.dtype)

    @property
    def valid(self) -> jax.Array:
        """(N,) bool: slots holding a written support (pad rows and
        never-written slots carry label -1 and are masked out of phase 1
        via the integer-exact SHORTLIST_MASK_PENALTY)."""
        return self.labels >= 0

    # -- programming ---------------------------------------------------------

    def calibrate(self, vectors: jax.Array) -> "MemoryStore":
        """Set the quantization range from a sample of embeddings (std
        clipping clamped to the data extent, paper Sec. 3.3). Must run
        before the first write -- re-calibrating a store that already holds
        programmed rows would silently make their quantized words
        inconsistent with the new range, so that raises."""
        try:
            written = int(self.size) > 0
        except jax.errors.JAXTypeError:
            # under tracing (eval_shape / jit) size has no concrete value,
            # so the guard cannot run -- it protects the eager setup path,
            # which is where calibration happens in practice
            written = False
        if written:
            raise ValueError(
                f"MemoryStore.calibrate: the store already holds "
                f"{int(self.size)} programmed row(s); their quantized words "
                f"were produced under the previous range and would become "
                f"inconsistent with the new one. Calibrate before the first "
                f"write (or build a fresh store and re-program it).")
        # the SAME std-clipped range hardware-aware training computes
        # (quantization.clip_range): calibrating on the sample the trainer
        # quantized over reproduces its range bit-for-bit
        lo, hi = quant_lib.clip_range(vectors, self.cfg.clip_std)
        return dataclasses.replace(self, lo=lo, hi=hi, calibrated=True)

    def write(self, vectors: jax.Array, labels: jax.Array) -> "MemoryStore":
        """Program a batch of float support embeddings (ring buffer).

        Write-time MCAM programming: quantization, the MTMC/AVSS LUT
        projection AND the string-grid layout are all materialised here,
        once, so every later search jits against constants. Batches larger
        than the capacity are rejected (a single batch would overwrite
        itself mid-write).

        On a multi-shard store the write is a shard_map write-through: each
        shard programs the slice of the batch that lands in its own ring
        segment, locally -- no cross-device scatter (streaming-ingest
        path; bit-identical to the unsharded write). A 1-shard (or
        unsharded) store takes the plain scatter path: there is no
        collective to avoid, and the scatter is 7.7x faster there
        (bench_engine_sharded write rows)."""
        n = vectors.shape[0]
        ring = self.cfg.capacity
        assert n <= ring, f"write batch ({n}) exceeds capacity ({ring})"
        if n == 0:
            return self
        if not self.calibrated:
            raise ValueError(
                "MemoryStore.write: writing to a never-calibrated store "
                "would quantize against the default (lo=0, hi=1) range and "
                "program garbage words; call store.calibrate(sample) before "
                "the first write (already-quantized supports go through "
                "MemoryStore.from_quantized instead).")
        v = _quantize(vectors, self.cfg.search.enc.levels, self.lo, self.hi)
        if self.mesh is not None and self.n_shards > 1:
            return self._program_streamed(v, labels, n)
        start = self.size % ring
        idx = (start + jnp.arange(n)) % ring
        return self._program(idx, v, labels, n)

    def _program(self, idx: jax.Array, v: jax.Array, labels: jax.Array,
                 n: int) -> "MemoryStore":
        enc = self.cfg.search.enc
        proj = kernel_ops.support_projection(v, enc)
        lab = labels.astype(jnp.int32)
        new_values = self.values.at[idx].set(v)
        new_labels = self.labels.at[idx].set(lab)
        s, r = self.sketch_sums.shape[0], self.sketch_sums.shape[1]
        if s == 1:
            # incremental sketch: the batch lands on DISTINCT ring slots
            # (n <= capacity), so adding the (new - old) bucket stats over
            # those slots is exact int32 arithmetic -- bit-identical to a
            # full rebuild from (new_values, new_labels)
            ds_new, dc_new = router_lib.bucket_sums(v, lab, r)
            ds_old, dc_old = router_lib.bucket_sums(self.values[idx],
                                                    self.labels[idx], r)
            sk_sums = self.sketch_sums + (ds_new - ds_old)[None]
            sk_counts = self.sketch_counts + (dc_new - dc_old)[None]
        else:
            # logically-partitioned store (mesh=None shard blocks): rows
            # may cross block boundaries, so rebuild -- one one-hot int
            # matmul, still scatter-free
            sk_sums, sk_counts = router_lib.build_sketch(
                new_values, new_labels, s, r)
        return dataclasses.replace(
            self,
            values=new_values,
            proj=self.proj.at[idx].set(proj),
            proj_packed=self.proj_packed.at[idx].set(
                kernel_ops.pack_projection(proj, enc)),
            s_grid=self.s_grid.at[idx].set(_layout(v, self.cfg)),
            labels=new_labels,
            sketch_sums=sk_sums,
            sketch_counts=sk_counts,
            size=self.size + n,
        )

    def _program_streamed(self, v: jax.Array, labels: jax.Array,
                          n: int) -> "MemoryStore":
        """Shard-local write-through: program a quantized batch into a
        row-sharded store with NO cross-device data movement.

        The batch (and its write-time projection/layout, computed once,
        replicated) enters the shard_map unsharded; each shard derives, for
        every row of its own contiguous block, which batch slot (if any)
        the ring assigns to that global row, and selects it in place. The
        ring index math is identical to the scatter path's
        `(start + arange(n)) % capacity`, inverted per row -- so the result
        is bit-identical, including wraparound across shard boundaries --
        and ragged pad rows (global row >= cfg.capacity) are never written.
        Compiled HLO carries no all-gather/all-to-all/scatter collectives
        (asserted in tests/test_store.py)."""
        from jax.experimental.shard_map import shard_map

        from repro.engine.sharded import _shard_index

        mesh, axes = self.mesh, self.axes
        ring = self.cfg.capacity
        enc = self.cfg.search.enc
        n_buckets = self.sketch_sums.shape[1]
        start = (self.size % ring).astype(jnp.int32)
        proj_b = kernel_ops.support_projection(v, enc)
        batch = (v, proj_b, kernel_ops.pack_projection(proj_b, enc),
                 _layout(v, self.cfg), labels.astype(jnp.int32))

        def local(start_: jax.Array, v_: jax.Array, proj_: jax.Array,
                  packed_: jax.Array, grid_: jax.Array, labels_: jax.Array,
                  values_loc: jax.Array, proj_loc: jax.Array,
                  packed_loc: jax.Array, grid_loc: jax.Array,
                  labels_loc: jax.Array) -> tuple[jax.Array, ...]:
            rows = values_loc.shape[0]
            g = _shard_index(mesh, axes) * jnp.int32(rows) \
                + jnp.arange(rows, dtype=jnp.int32)       # global row ids
            # batch slot that the ring assigns to global row g (jnp.mod is
            # non-negative for a positive divisor, so pre-start rows wrap)
            j = jnp.mod(g - start_, jnp.int32(ring))
            written = (j < n) & (g < ring)                # pads stay pads
            jc = jnp.minimum(j, jnp.int32(n - 1))         # safe gather idx

            def sel(new: jax.Array, old: jax.Array) -> jax.Array:
                w = written.reshape((-1,) + (1,) * (old.ndim - 1))
                return jnp.where(w, new[jc].astype(old.dtype), old)

            new_vals = sel(v_, values_loc)
            new_labs = sel(labels_, labels_loc)
            # shard-local router sketch rebuild over the POST-write block:
            # one-hot int matmul (router.bucket_sums), so the compiled HLO
            # stays free of scatter AND collectives like the rest of the
            # write-through; exact int32, bit-identical to the scatter
            # path's sketch for the same rows
            sk_sums, sk_counts = router_lib.bucket_sums(new_vals, new_labs,
                                                        n_buckets)
            return (new_vals, sel(proj_, proj_loc),
                    sel(packed_, packed_loc),
                    sel(grid_, grid_loc), new_labs,
                    sk_sums[None], sk_counts[None])

        out = shard_map(
            local, mesh=mesh,
            in_specs=(P(),) * 6 + (P(axes),) * 5,
            out_specs=(P(axes),) * 7,
            check_rep=False,
        )(start, *batch, self.values, self.proj, self.proj_packed,
          self.s_grid, self.labels)
        return dataclasses.replace(
            self, values=out[0], proj=out[1], proj_packed=out[2],
            s_grid=out[3], labels=out[4],
            sketch_sums=out[5], sketch_counts=out[6], size=self.size + n)

    def quantize_queries(self, queries: jax.Array) -> jax.Array:
        """Float embeddings -> quantized query words ([0, 4) for AVSS,
        [0, levels) for SVSS). Integer queries pass through untouched
        (already quantized, e.g. the episodic evaluation path). Float
        queries on a never-calibrated store raise: quantizing against the
        default (lo=0, hi=1) range returns garbage words."""
        if jnp.issubdtype(queries.dtype, jnp.integer):
            return queries
        if not self.calibrated:
            raise ValueError(
                "MemoryStore.quantize_queries: float queries on a "
                "never-calibrated store (e.g. fresh create() or "
                "from_quantized()) would quantize against the default "
                "(lo=0, hi=1) range and return garbage words; call "
                "store.calibrate(sample) first, or pass pre-quantized "
                "integer queries.")
        cfg = self.cfg.search
        levels = 4 if cfg.mode == "avss" else cfg.enc.levels
        return _quantize(queries, levels, self.lo, self.hi)

    # -- sharding ------------------------------------------------------------

    def shard(self, mesh: Mesh | None = None,
              axes: Sequence[str] = ("data",), *,
              n_shards: int | None = None,
              residency: str = "device") -> "MemoryStore":
        """Row-shard the store and record the partition as a store property
        (RetrievalEngine.search dispatches on it).

        Two placements:

        * `shard(mesh, axes)` -- device-shard over mesh `axes` (today's
          path). The router sketch is rebuilt at the new shard count and
          row-sharded alongside the data.
        * `shard(n_shards=S)` -- LOGICAL partition, no mesh: the store
          keeps its global arrays but the sketch records S contiguous row
          blocks, which `SearchRequest.nprobe` routes over on a single
          device. With `residency="host"` the arrays are additionally
          placed in host (CPU) memory -- such a store is searched through
          `engine/pager.ShardPager`, which pages the visited blocks into
          device HBM (`engine.search` on it raises).

        Ragged splits are supported: when the row count does not divide the
        shard count, the store is padded with label -1 rows programmed to
        value 0 -- indistinguishable from never-written slots, so the mask
        penalty ranks them after every valid row and top-k results stay
        bit-identical to the unsharded search for k <= the unpadded row
        count.

        Idempotent: re-sharding always starts from the LOGICAL
        `cfg.capacity` rows (any ragged pad rows from a previous shard are
        dropped first), so pads never accumulate and
        `shard(mesh_a).shard(mesh_b)` equals `shard(mesh_b)` exactly."""
        if residency not in ("device", "host"):
            raise ValueError(f"unknown residency {residency!r}: expected "
                             f"'device' or 'host'")
        if mesh is not None:
            if residency != "device":
                raise ValueError(
                    "MemoryStore.shard: mesh-sharded stores are device-"
                    "resident; residency='host' applies to logical "
                    "partitions (shard(n_shards=S, residency='host')) "
                    "paged by engine/pager.ShardPager")
            axes = tuple(axes)
            n_shards = int(np.prod([mesh.shape[a] for a in axes]))
        elif n_shards is None or n_shards < 1:
            raise ValueError("MemoryStore.shard: pass a mesh or "
                             "n_shards >= 1")
        base = self._unpad()
        store = base._pad_rows((-base.capacity) % n_shards)
        sk_sums, sk_counts = router_lib.build_sketch(
            store.values, store.labels, n_shards,
            self.sketch_sums.shape[1])
        store = dataclasses.replace(store, sketch_sums=sk_sums,
                                    sketch_counts=sk_counts)
        if mesh is None:
            if residency == "host":
                dev = _host_device()
                if dev is not None:
                    moved = {f: jax.device_put(getattr(store, f), dev)
                             for f in _DATA_FIELDS}
                    store = dataclasses.replace(store, **moved)
            return dataclasses.replace(store, mesh=None, axes=(),
                                       residency=residency)
        row = NamedSharding(mesh, P(axes))
        rep = NamedSharding(mesh, P())
        return dataclasses.replace(
            store,
            values=jax.device_put(store.values, row),
            proj=jax.device_put(store.proj, row),
            proj_packed=jax.device_put(store.proj_packed, row),
            s_grid=jax.device_put(store.s_grid, row),
            labels=jax.device_put(store.labels, row),
            size=jax.device_put(store.size, rep),
            lo=jax.device_put(store.lo, rep),
            hi=jax.device_put(store.hi, rep),
            sketch_sums=jax.device_put(store.sketch_sums, row),
            sketch_counts=jax.device_put(store.sketch_counts, row),
            mesh=mesh, axes=axes, residency="device",
        )

    def _unpad(self) -> "MemoryStore":
        """Back to the logical view: drop ragged-shard pad rows and reset
        the router sketch to the unpartitioned S=1 block (so re-`shard`
        always starts from the same logical store, whatever partition came
        before). Does NOT move arrays between memories -- `shard` handles
        placement."""
        n = self.cfg.capacity
        base = self
        if self.capacity != n:
            base = dataclasses.replace(
                self, values=self.values[:n], proj=self.proj[:n],
                proj_packed=self.proj_packed[:n],
                s_grid=self.s_grid[:n], labels=self.labels[:n])
        if base.sketch_sums.shape[0] != 1 or base.residency != "device":
            sk_sums, sk_counts = router_lib.build_sketch(
                base.values, base.labels, 1, base.sketch_sums.shape[1])
            base = dataclasses.replace(base, sketch_sums=sk_sums,
                                       sketch_counts=sk_counts,
                                       residency="device")
        return base

    def _pad_rows(self, pad: int) -> "MemoryStore":
        if pad == 0:
            return self
        enc = self.cfg.search.enc
        zeros = jnp.zeros((pad, self.dim), jnp.int32)
        proj_pad = kernel_ops.support_projection(zeros, enc)
        cat = lambda a, b: jnp.concatenate([a, b], axis=0)
        return dataclasses.replace(
            self,
            values=cat(self.values, zeros),
            proj=cat(self.proj, proj_pad),
            proj_packed=cat(self.proj_packed,
                            kernel_ops.pack_projection(proj_pad, enc)),
            s_grid=cat(self.s_grid, _layout(zeros, self.cfg)),
            labels=cat(self.labels, jnp.full((pad,), -1, jnp.int32)),
        )


def _layout(values: jax.Array, cfg: MemoryConfig) -> jax.Array:
    """Write-time string-grid layout: (n, d) -> (n, seg, L, sl) int8 codes
    (code words are in [0, 3]; int8 is what the kernels consume)."""
    grid = avss_lib.layout_support(values, cfg.search.enc,
                                   cfg.search.mcam.string_len)
    return grid.astype(jnp.int8)
