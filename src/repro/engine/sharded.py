"""Sharded retrieval: block-parallel search over a row-sharded store.

Two flavours:

  sharded_two_phase_search   per-shard shortlist + exact noisy rescore,
                             then an all-gather of the per-shard top-k
                             (dist, index, label) TRIPLET only + global
                             top-k merge; the merged candidates' votes are
                             recovered with one (B, k) psum (each global
                             row is owned by exactly one shard, so the
                             ownership-masked partial sums are exact --
                             no vote tensor ever rides the all-gather).
                             Votes are BIT-IDENTICAL to the single-device
                             two-phase. Ragged stores arrive pre-padded by
                             MemoryStore.shard (label -1 pad rows, masked
                             by the phase-1 penalty).
  sharded_ideal_search       ideal-digital-distance only (the cheap serving
                             path formerly inlined in core/memory.py).

Both paths share ONE per-shard shortlist implementation with the unsharded
engine: when a shard's local rows reach `fused_min_rows` (or the backend is
'fused'), phase 1 runs the fused Pallas shortlist kernel
(kernels/shortlist.py, HBM O(B*k_loc + N_loc*4d)) inside the shard_map
body -- masked rows (ragged pads, empty slots) are penalised NATIVELY in
the kernel with the integer-exact SHORTLIST_MASK_PENALTY, and ragged
(non-tile-aligned) local blocks are padded inside the kernel wrapper.
Below the threshold (and on the 'ref' backend) the readable dense local
matmul + lax.top_k remains, bit-identically.

Exactness argument for the two-phase path (verified by
tests/test_engine.py::test_sharded_two_phase_bit_identical):

* Shortlist distances are integer-valued f32 (AVSS LUT entries are small
  integers, one-hot queries are 0/1, f32 accumulation is exact below 2**24),
  so every shard computes the same exact distance a single device would --
  fused or dense.
* `jax.lax.top_k` ranks by (value, index), and the fused kernel reproduces
  that order exactly (ties included): a support in the GLOBAL top-k is
  necessarily in its shard's LOCAL top-k under the same order, so no global
  candidate is lost by local pruning.
* The all-gather stacks shards in mesh-axis-major order -- the same order a
  row-sharded array is laid out in -- so a STABLE argsort over the gathered
  distances resolves ties by ascending global support index, exactly like
  single-device top_k.
* The rescore feeds GLOBAL support indices to the noise counters, so the
  noisy vote of support n for query b is the same number on every shard.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

if TYPE_CHECKING:
    from jax.sharding import Mesh

    from repro.core.avss import SearchConfig


def _shard_index(mesh: Mesh, axes: Sequence[str]) -> jax.Array:
    """Row-major linear index of this shard over `axes` (inside shard_map)."""
    shard = jnp.int32(0)
    for a in axes:
        shard = shard * jnp.int32(mesh.shape[a]) \
            + jax.lax.axis_index(a).astype(jnp.int32)
    return shard


def _gather_candidates(x: jax.Array, axes: Sequence[str]) -> jax.Array:
    """(B, kk) per-shard -> (B, S * kk) shard-major (ascending global rows)."""
    ax = axes[0] if len(axes) == 1 else tuple(axes)
    stacked = jax.lax.all_gather(x, ax, tiled=False).reshape(-1, *x.shape)
    return jnp.moveaxis(stacked, 0, 1).reshape(x.shape[0], -1)


def _use_fused(backend: str, rows_loc: int,
               fused_min_rows: int | None) -> bool:
    """Shared shard-local dispatch rule: the fused Pallas shortlist kernel
    engages on any kernel backend once a shard's local rows reach the
    threshold, and always on the 'fused' backend; the 'ref' backend (and
    fused_min_rows=None, the raw-array default) keeps the dense local
    matmul as the readable reference."""
    if backend == "fused":
        return True
    return (backend != "ref" and fused_min_rows is not None
            and rows_loc >= fused_min_rows)


def _local_shortlist(q1h: jax.Array, proj_loc: jax.Array,
                     valid_loc: jax.Array, k_loc: int, *, fused: bool,
                     packed: jax.Array | None = None,
                     pack_bits: int | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Block shortlist shared by every dispatch site (per shard inside the
    shard_map bodies here, and the unsharded dense `ideal` route in
    engine.py): top-k_loc of the rows by exact integer LUT distance
    (+ native mask penalty), fused or dense -- bit-identical either way
    (the kernel reproduces lax.top_k's (distance, row) order). When the
    store provides its bit-packed projection (`packed`/`pack_bits`), the
    fused kernel streams that 4-8x smaller operand instead of proj_loc."""
    if fused:
        from repro.kernels import shortlist as shortlist_kernel
        if packed is not None:
            return shortlist_kernel.lut_shortlist_pallas(
                q1h, None, k_loc, valid=valid_loc, packed=packed,
                pack_bits=pack_bits)
        return shortlist_kernel.lut_shortlist_pallas(
            q1h, proj_loc, k_loc, valid=valid_loc)
    from repro.kernels import ops as kernel_ops
    dist = q1h @ proj_loc.astype(jnp.float32).T            # (B, N_loc)
    dist = dist + jnp.where(valid_loc, 0.0,
                            kernel_ops.SHORTLIST_MASK_PENALTY)[None]
    neg, idx = jax.lax.top_k(-dist, k_loc)
    return -neg, idx


def sharded_two_phase_search(q_values: jax.Array, s_values: jax.Array,
                             cfg: SearchConfig, mesh: Mesh,
                             axes: Sequence[str] = ("data",),
                             k: int = 64, valid: jax.Array | None = None,
                             labels: jax.Array | None = None,
                             s_grid: jax.Array | None = None,
                             proj: jax.Array | None = None,
                             packed: jax.Array | None = None,
                             pack_bits: int | None = None,
                             backend: str = "ref",
                             fused_min_rows: int | None = None
                             ) -> dict[str, jax.Array]:
    """Two-phase AVSS over a store row-sharded on `axes`.

    q_values: (B, d) ints in [0, 4), replicated.
    s_values: (N, d) ints, row-sharded (N divisible by the shard count;
    `MemoryStore.shard` pads ragged splits with label -1 rows first).
    valid: optional (N,) bool, row-sharded like s_values; masked rows get
    the integer-exact SHORTLIST_MASK_PENALTY on their phase-1 distance.
    labels: optional (N,) int32, row-sharded. When given, each shard looks
    up its local candidates' labels and contributes them to the all-gather
    (the merge then never touches the globally-sharded label column), and
    the result gains a "labels" key.
    s_grid: optional (N, seg, L, sl) write-time string grid (row-sharded,
    MemoryStore.s_grid); omitted -> each shard lays out its rows here.
    proj: optional (N, 4d) write-time LUT projection (row-sharded,
    MemoryStore.proj); omitted -> each shard projects its rows here.
    packed: optional bit-packed projection (row-sharded,
    MemoryStore.proj_packed); the fused per-shard shortlist then streams
    this 4-8x smaller operand instead of proj, bit-identically.
    backend / fused_min_rows: per-shard shortlist dispatch (see
    `_use_fused`); the default (ref, None) keeps the dense local matmul.
    Returns {votes (B, k), dist (B, k), indices (B, k) global rows
    [, labels (B, k)], iterations} -- bit-identical to
    RetrievalEngine.two_phase(q, s, k, valid) on a single device,
    whichever shortlist path engages.
    """
    from jax.experimental.shard_map import shard_map
    from repro.core import avss as avss_lib
    from repro.kernels import ops as kernel_ops

    assert cfg.mode == "avss", "two-phase search shortlists with the AVSS LUT"
    enc = cfg.enc
    sl = cfg.mcam.string_len
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    N = s_values.shape[0]
    assert N % n_shards == 0, (
        f"store rows ({N}) must divide evenly over {n_shards} shards "
        f"(MemoryStore.shard pads ragged splits)")
    k = min(k, N)
    k_loc = min(k, N // n_shards)
    fused = _use_fused(backend, N // n_shards, fused_min_rows)

    q1h = kernel_ops.query_onehot(q_values, jnp.float32)       # (B, 4d)
    q_grid = avss_lib.layout_query(q_values, enc, "avss", sl)
    weights = enc.weights_array()
    thresholds = jnp.asarray(cfg.mcam.thresholds())
    # LUT built eagerly OUTSIDE the shard_map trace (it is a compile-time
    # constant of the encoding) and closed over by the local function.
    from repro.core.encodings import avss_sum_lut
    lut = jnp.asarray(avss_sum_lut(enc), jnp.float32)          # (4, levels)
    if valid is None:
        # keep the shard_map arity fixed; +0.0 is exact, parity unaffected
        valid = jnp.ones((N,), bool)
    # optional row-sharded extras keep the arity dynamic but the specs tied
    extras: list[jax.Array] = []
    extra_specs: list[P] = []
    if labels is not None:
        extras.append(labels)
        extra_specs.append(P(axes))
    if s_grid is not None:
        extras.append(s_grid)
        extra_specs.append(P(axes))
    if proj is not None:
        extras.append(proj)
        extra_specs.append(P(axes))
    if packed is not None:
        extras.append(packed)
        extra_specs.append(P(axes))
        if pack_bits is None:
            # fallback for callers that packed from `proj` right here; the
            # engine passes store.pack_bits (the authoritative pack-time
            # width) so a bf16-vs-f32 proj dtype can never mis-unpack
            pack_bits = kernel_ops.projection_pack_bits(
                enc, proj.dtype if proj is not None else jnp.bfloat16)
    else:
        pack_bits = None
    ax = axes[0] if len(axes) == 1 else tuple(axes)

    def local(q1h_: jax.Array, q_grid_: jax.Array, s_loc: jax.Array,
              valid_loc: jax.Array,
              *rest: jax.Array) -> tuple[jax.Array, ...]:
        rest_l = list(rest)
        labels_loc = rest_l.pop(0) if labels is not None else None
        s_grid_loc = rest_l.pop(0) if s_grid is not None else None
        proj_loc = rest_l.pop(0) if proj is not None else None
        packed_loc = rest_l.pop(0) if packed is not None else None
        offset = _shard_index(mesh, axes) * jnp.int32(s_loc.shape[0])
        # phase 1 on local rows: exact integer-valued distances, fused
        # kernel or dense MXU matmul (same LUT projection as
        # kernels/ops.support_projection, materialised at write time when
        # the store provides `proj` / its bit-packed `packed` twin)
        if proj_loc is None:
            proj_loc = lut.T[s_loc].reshape(s_loc.shape[0], -1)  # (N_loc, 4d)
        d_loc, idx_loc = _local_shortlist(q1h_, proj_loc, valid_loc, k_loc,
                                          fused=fused, packed=packed_loc,
                                          pack_bits=pack_bits)
        gidx = idx_loc + offset
        # phase 2 on local candidates, GLOBAL indices for the noise counters
        if s_grid_loc is None:                         # read-time layout
            s_grid_loc = avss_lib.layout_support(s_loc, enc, sl)
        votes = kernel_ops.rescore_shortlist(
            q_grid_, s_grid_loc, idx_loc, weights, cfg, thresholds,
            noise_idx=gidx)
        # merge: all-gather ONLY the per-shard (dist, global index[, label])
        # triplet -- a stable sort by distance == (distance, global row)
        # order selects the global top-k. Votes never ride the gather: each
        # selected global row is owned by exactly one shard (shard offsets
        # partition the index space, local candidates are distinct), so the
        # ownership-masked partial sum holds that shard's rescored vote and
        # zeros elsewhere, and one (B, k) psum recovers the merged votes
        # exactly (adding f32 zeros is exact -- bit-parity preserved).
        d_all = _gather_candidates(d_loc, axes)
        i_all = _gather_candidates(gidx, axes)
        order = jnp.argsort(d_all, axis=-1, stable=True)[:, :k]
        take = lambda x: jnp.take_along_axis(x, order, axis=1)
        d_k, i_k = take(d_all), take(i_all)
        own = i_k[:, :, None] == gidx[:, None, :]         # (B, k, k_loc)
        v_part = jnp.sum(jnp.where(own, votes[:, None, :], 0.0), axis=2)
        v_k = jax.lax.psum(v_part, ax)
        outs = (v_k, d_k, i_k)
        if labels_loc is not None:
            l_all = _gather_candidates(labels_loc[idx_loc], axes)
            outs = outs + (take(l_all),)
        return outs

    out = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(), P(axes), P(axes), *extra_specs),
        out_specs=(P(),) * (3 + (labels is not None)),
        check_rep=False,
    )(q1h, q_grid, s_values, valid, *extras)
    res = {"votes": out[0], "dist": out[1], "indices": out[2],
           "iterations": avss_lib.search_iterations(
               q_values.shape[-1], enc, "avss", sl)}
    if labels is not None:
        res["labels"] = out[3]
    return res


def sharded_ideal_search(q_onehot: jax.Array, proj: jax.Array,
                         labels: jax.Array, mesh: Mesh,
                         axes: Sequence[str] = ("data",),
                         k: int = 16, backend: str = "ref",
                         fused_min_rows: int | None = None,
                         packed: jax.Array | None = None,
                         pack_bits: int | None = None,
                         enc: Any = None) -> dict[str, jax.Array]:
    """Ideal-digital-distance block search (no rescore; cheap serving path).

    q_onehot: (B, 4d) replicated query one-hots; proj: (N, 4d) row-sharded
    LUT projections; labels: (N,) row-sharded (< 0 marks empty slots --
    their distance carries the integer-exact SHORTLIST_MASK_PENALTY, the
    same masking the two-phase and unsharded ideal paths use, so results
    stay bit-identical to the single-device fused/dense ideal search even
    when masked rows reach the top-k).
    backend / fused_min_rows: per-shard shortlist dispatch (see
    `_use_fused`); above the threshold each shard streams through the fused
    Pallas shortlist kernel instead of the dense (B, N_loc) local matmul.
    packed / enc: optional bit-packed projection (row-sharded,
    MemoryStore.proj_packed) and its encoding; the fused path then streams
    the 4-8x smaller operand, bit-identically.
    Collective volume is O(B * k * shards), independent of capacity.
    Returns {dist, votes=-dist, labels, indices} each (B, k').
    """
    from jax.experimental.shard_map import shard_map

    rows_loc = proj.shape[0] // int(np.prod([mesh.shape[a] for a in axes]))
    fused = _use_fused(backend, rows_loc, fused_min_rows)
    extras: list[jax.Array] = []
    extra_specs: list[P] = []
    if packed is not None and (pack_bits is not None or enc is not None):
        extras.append(packed)
        extra_specs.append(P(axes))
        if pack_bits is None:            # fallback: derive from enc + proj
            from repro.kernels import ops as kernel_ops
            pack_bits = kernel_ops.projection_pack_bits(enc, proj.dtype)
    else:
        pack_bits = None

    def local(qr: jax.Array, proj_loc: jax.Array, labels_loc: jax.Array,
              *rest: jax.Array
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
        packed_loc = rest[0] if rest else None
        offset = _shard_index(mesh, axes) * jnp.int32(proj_loc.shape[0])
        kk = min(k, proj_loc.shape[0])
        d_loc, idx = _local_shortlist(qr, proj_loc, labels_loc >= 0, kk,
                                      fused=fused, packed=packed_loc,
                                      pack_bits=pack_bits)
        d_all = _gather_candidates(d_loc, axes)
        l_all = _gather_candidates(labels_loc[idx], axes)
        i_all = _gather_candidates(idx + offset, axes)
        order = jnp.argsort(d_all, axis=-1, stable=True)[:, :k]
        take = lambda x: jnp.take_along_axis(x, order, axis=1)
        return take(d_all), take(l_all), take(i_all)

    dist, labels_out, indices = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(axes), P(axes), *extra_specs),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )(q_onehot, proj, labels, *extras)
    return {"dist": dist, "labels": labels_out, "votes": -dist,
            "indices": indices}
