"""ShardPager: beyond-HBM serving -- host-resident shards paged on demand.

The memory-hierarchy bottom half of ROADMAP item 2: a partitioned store
(`MemoryStore.shard(n_shards=S, residency="host")`) keeps its row blocks
in host memory, and only a small LRU working set of shard blocks lives in
device HBM. Per batch:

1. the router sketch (device-resident, tiny) is scored with one jitted
   matmul (`engine/router.route_scores`);
2. the top-`nprobe` shards per query are paged into device slot tables
   (`jax.device_put` explicit copies -- `jax.transfer_guard`-clean), LRU
   evicting cold slots;
3. ONE jitted program -- the same `_routed_block_search` core
   `RetrievalEngine.search(nprobe=p)` uses on device-resident stores --
   searches the resident tables, so the result is bit-identical to the
   routed search on a fully device-resident twin of the store
   (tests/test_pager.py), which is itself bit-identical to brute force
   restricted to the visited shards;
4. the best not-yet-resident shard (by aggregate router score) is staged
   asynchronously into a spare slot (double-buffering: on real
   accelerators the host->device copy overlaps the search dispatched in
   step 3; `slots >= nprobe + 1` leaves room for it).

Addressable capacity is host memory, not HBM: HBM holds
O(slots * rows_per_shard) plus the sketch, independent of S.

>>> import jax.numpy as jnp
>>> from repro.core.avss import SearchConfig
>>> from repro.engine import (MemoryStore, RetrievalEngine,
...                           SearchRequest)
>>> from repro.engine.pager import ShardPager
>>> cfg = SearchConfig("mtmc", cl=4, mode="avss", use_kernel="ref")
>>> vals = (jnp.arange(64).reshape(32, 2) * 3) % 10
>>> store = MemoryStore.from_quantized(vals, jnp.arange(32) % 8, cfg)
>>> req = SearchRequest(mode="two_phase", k=4, nprobe=2)
>>> pager = ShardPager(store.shard(n_shards=4, residency="host"),
...                    RetrievalEngine(cfg), slots=3)
>>> res = pager.search(jnp.array([[1, 2]]), req)
>>> ref = RetrievalEngine(cfg).search(          # device-resident twin
...     store.shard(n_shards=4), jnp.array([[1, 2]]), req)
>>> bool(jnp.array_equal(res.votes, ref.votes))
True
>>> len(pager.resident())             # the nprobe=2 visited shards
2
"""

from __future__ import annotations

from collections import OrderedDict
from functools import partial
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import router as router_lib
from repro.engine.api import SearchRequest, SearchResult
from repro.engine.engine import RetrievalEngine
from repro.engine.store import MemoryStore

#: host-side block tables a slot holds (the leaves a routed search reads).
_BLOCK_FIELDS = ("proj", "proj_packed", "s_grid", "labels")


class ShardPager:
    """LRU pager over a host-partitioned MemoryStore (module docstring).

    store:   partitioned store (`shard(n_shards=S, ...)`, mesh-less);
             `residency="host"` is the intended placement -- the pager
             reads the blocks as host numpy views and owns all device
             placement itself.
    engine:  the RetrievalEngine whose routed-search core (and backend /
             fused threshold) the paged search runs.
    slots:   device-resident shard slots (default min(S, 4)). A search
             needs the BATCH's visited-shard union (at most
             `B * nprobe`, typically far fewer for correlated queries)
             to fit in `slots`; head-room beyond `nprobe` enables the
             prefetch slot.
    prefetch: stage the next-best shard after each search (step 4).
    """

    def __init__(self, store: MemoryStore, engine: RetrievalEngine,
                 slots: int | None = None, prefetch: bool = True) -> None:
        if store.mesh is not None or store.n_shards < 2:
            raise ValueError(
                "ShardPager: pass a logically partitioned store "
                "(MemoryStore.shard(n_shards=S[, residency='host'])); "
                "mesh-sharded stores are already device-resident")
        self.store = store
        self.engine = engine
        self.n_shards = store.n_shards
        self.rows = store.capacity // self.n_shards
        self.slots = min(self.n_shards, 4) if slots is None else slots
        if not 1 <= self.slots <= self.n_shards:
            raise ValueError(f"ShardPager: slots={self.slots} must be in "
                             f"[1, n_shards={self.n_shards}]")
        self.prefetch = prefetch
        self.pages_in = 0                     # host->device block copies

        # host blocks: zero-copy numpy views per leaf, (S, rows, ...)
        s = self.n_shards
        self._host = {
            f: np.asarray(getattr(store, f)).reshape(
                (s, self.rows) + np.asarray(getattr(store, f)).shape[1:])
            for f in _BLOCK_FIELDS if getattr(store, f) is not None}

        # device slot tables (m, rows, ...) + the tiny resident sketch
        dev = jax.devices()[0]
        self._tables = {
            f: jax.device_put(jnp.zeros((self.slots,) + h.shape[1:],
                                        h.dtype), dev)
            for f, h in self._host.items()}
        self._sketch = (jax.device_put(store.sketch_sums, dev),
                        jax.device_put(store.sketch_counts, dev))
        self._lru: OrderedDict[int, int] = OrderedDict()  # shard -> slot
        self._staged: dict[int, dict[str, jax.Array]] = {}

        enc = engine.cfg.enc
        self._route = jax.jit(lambda q, su, c: router_lib.route_scores(
            q, su, c, enc))
        pack_bits = store.pack_bits

        @partial(jax.jit, static_argnames=("req",))
        def _jsearch(proj_t: jax.Array, packed_t: jax.Array | None,
                     sgrid_t: jax.Array, labels_t: jax.Array,
                     shard_of: jax.Array, q: jax.Array, slot_ids: jax.Array,
                     req: SearchRequest) -> SearchResult:
            return engine._routed_block_search(
                q, slot_ids, shard_of, proj_t, packed_t, sgrid_t,
                labels_t, req, pack_bits)

        self._jsearch = _jsearch
        # slot is STATIC (at most `slots` variants) so installing pages no
        # scalar to the device -- steady-state stays transfer-guard-clean
        self._install = jax.jit(
            lambda table, block, slot: table.at[slot].set(block),
            static_argnums=2, donate_argnums=0)

    # -- residency ----------------------------------------------------------

    def resident(self) -> list[int]:
        """Currently resident shard ids, ascending."""
        return sorted(self._lru)

    def _shard_of(self) -> np.ndarray:
        """(slots,) slot -> global shard id (-1 for an empty slot)."""
        out = np.full((self.slots,), -1, np.int32)
        for shard, slot in self._lru.items():
            out[slot] = shard
        return out

    def _stage(self, shard: int) -> None:
        """Begin the (async on real backends) host->device copy of one
        shard's blocks. `jax.device_put` is an EXPLICIT transfer, so
        staging is clean under `jax.transfer_guard("disallow")`."""
        if shard in self._lru or shard in self._staged:
            return
        dev = jax.devices()[0]
        self._staged[shard] = {
            f: jax.device_put(h[shard], dev) for f, h in self._host.items()}

    def ensure(self, shard_ids: Iterable[int]) -> dict[int, int]:
        """Page the given shards in (LRU-evicting cold slots) and return
        the shard -> slot map. Raises if they cannot fit at once."""
        want = sorted(set(int(s) for s in shard_ids))
        if len(want) > self.slots:
            raise ValueError(
                f"ShardPager: {len(want)} shards requested at once but "
                f"only {self.slots} device slots (raise `slots` or lower "
                f"`nprobe`)")
        for shard in want:
            if shard in self._lru:
                self._lru.move_to_end(shard)
                continue
            if len(self._lru) < self.slots:
                slot = len(self._lru)
            else:
                # evict the least-recently-used shard NOT in this
                # working set (the `want` set fits, so one exists)
                victim = next(s for s in self._lru if s not in want)
                slot = self._lru.pop(victim)
            blocks = self._staged.pop(shard, None)
            if blocks is None:
                dev = jax.devices()[0]
                blocks = {f: jax.device_put(h[shard], dev)
                          for f, h in self._host.items()}
            for f, block in blocks.items():
                self._tables[f] = self._install(self._tables[f], block,
                                                int(slot))
            self._lru[shard] = slot
            self.pages_in += 1
        return {s: self._lru[s] for s in want}

    # -- search --------------------------------------------------------------

    def search(self, queries: jax.Array,
               request: SearchRequest) -> SearchResult:
        """Routed search over the paged store -- bit-identical to
        `RetrievalEngine.search(device_twin, queries, request)` with the
        same nprobe (tests/test_pager.py)."""
        p = request.nprobe
        if p is None or not 1 <= p <= self.n_shards:
            raise ValueError(
                f"ShardPager.search: request.nprobe must be in "
                f"[1, n_shards={self.n_shards}], got {p}")
        if p > self.slots:
            raise ValueError(f"ShardPager.search: nprobe={p} exceeds the "
                             f"{self.slots} device slots")
        dev = jax.devices()[0]
        q = jax.device_put(self.store.quantize_queries(queries), dev)
        scores = np.asarray(jax.device_get(
            self._route(q, *self._sketch)))            # (B, S) on host
        # same selection rule as router.top_shards: smallest score first,
        # ties to the lowest shard id, then ascending shard id per query
        order = np.argsort(scores, axis=1, kind="stable")
        visited = np.sort(order[:, :p], axis=1)        # (B, p) shard ids
        slot_map = self.ensure(np.unique(visited))
        slot_ids = jax.device_put(
            np.vectorize(slot_map.__getitem__)(visited).astype(np.int32),
            dev)
        shard_of = jax.device_put(self._shard_of(), dev)
        res = self._jsearch(
            self._tables["proj"],
            self._tables.get("proj_packed"),
            self._tables["s_grid"], self._tables["labels"],
            shard_of, q, slot_ids, request)
        if self.prefetch and p < self.n_shards and len(self._staged) < 2:
            # double-buffer: while the search above executes, stage the
            # (p+1)-th-best shard by aggregate score rank across the batch
            candidates = order[:, p]
            nxt = int(np.bincount(candidates,
                                  minlength=self.n_shards).argmax())
            self._stage(nxt)
        return res
