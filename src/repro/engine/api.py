"""The typed request/result contract of the unified retrieval API.

Every search in the framework goes through one entry point,

    RetrievalEngine.search(store, queries, SearchRequest) -> SearchResult

replacing the five overlapping ad-hoc paths of the pre-redesign API
(`engine.full` / `engine.two_phase` / `engine.sharded_two_phase`,
`memory.search` / `memory.distributed_search`) and their untyped result
dicts. The request names WHAT to search (mode, k, backend, shard axes,
fused-shortlist threshold); the store (repro/engine/store.py) carries the
programmed memory and its sharding; the result is a registered pytree safe
to return from jit.

Old -> new mapping (the old entry points remain as thin shims; the full
table with the deprecation policy lives in docs/migration.md):

  engine.full(q, s)                      search(store, q, mode="full")
  engine.two_phase(q, s, k)              search(store, q, mode="two_phase", k)
  engine.sharded_two_phase(q, s, mesh)   search(store.shard(mesh, axes), q,
                                                mode="two_phase", k)
  memory.search(state, q, cfg, ...)      search(store, q, ...)
  memory.distributed_search(state, ...)  search(store.shard(mesh, axes), ...)
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

MODES = ("full", "two_phase", "ideal")


@dataclasses.dataclass(frozen=True)
class SearchRequest:
    """What to search. Hashable -> usable as a jit-static argument.

    mode:    'full'       exact noisy MCAM search of every store row;
             'two_phase'  shortlist by ideal digital distance + exact
                          noisy rescore of the top-k candidates (the
                          production serving path);
             'ideal'      ideal-digital-distance top-k only, no rescore
                          (the cheapest serving path).
    k:       candidate count for 'two_phase' / 'ideal' (ignored by 'full').
    backend: 'auto' defers to the engine's backend; any other value
             ('ref' | 'pallas' | 'mxu' | 'fused') overrides it per request.
    axes:    shard axes override; None defers to the store's own sharding
             (`MemoryStore.shard` records mesh + axes on the store).
    fused_min_rows: per-request override of the engine's fused-shortlist
             row threshold (None defers to the engine). Shortlists -- the
             'ideal' mode and phase 1 of 'two_phase', per SHARD-LOCAL block
             on a sharded store -- stream through the fused Pallas kernel
             (repro/kernels/shortlist.py) once the row count reaches this
             threshold; results are bit-identical either way, so this is
             purely a performance knob (e.g. for applying a measured TPU
             dense-vs-fused crossover without a code change).
    noisy:   per-request override of SearchConfig.noisy (None defers to
             the config). noisy=False serves the NOISELESS hardware
             forward on any mode/backend/sharding -- the serving side of
             the train/serve parity contract: noiseless votes are
             bit-identical to hardware-aware training's in-episode scores
             (`RetrievalEngine.episode_votes`) on the same support set.
    nprobe:  shards visited per query ('two_phase' / 'ideal' only). On a
             partitioned store (`MemoryStore.shard`), nprobe=p < n_shards
             engages the phase-0 router (engine/router.py): the per-shard
             summary sketch is scored with one small matmul and phase 1/2
             run only on the top-p shards -- bit-identical to brute force
             restricted to those shards (same SHORTLIST_MASK_PENALTY,
             same (distance, index) lex merge). None (the default) and
             nprobe >= n_shards reproduce the exhaustive all-shards
             search byte-for-byte. Recall-vs-nprobe is a measured serving
             knob (benchmarks/bench_router.py, BENCH_router.json).

    >>> SearchRequest(mode="ideal", k=8).mode
    'ideal'
    >>> SearchRequest().k                  # default: two-phase, k=64
    64
    >>> SearchRequest(mode="nearest")
    Traceback (most recent call last):
        ...
    ValueError: unknown search mode 'nearest'; expected one of \
('full', 'two_phase', 'ideal')
    """

    mode: str = "two_phase"
    k: int = 64
    backend: str = "auto"
    axes: tuple[str, ...] | None = None
    fused_min_rows: int | None = None
    noisy: bool | None = None
    nprobe: int | None = None

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(
                f"unknown search mode {self.mode!r}; expected one of {MODES}")
        if self.nprobe is not None:
            if self.mode == "full":
                raise ValueError(
                    "SearchRequest: nprobe routes the shortlist modes "
                    "('two_phase' / 'ideal'); mode='full' scores every "
                    "row by definition")
            if self.nprobe < 1:
                raise ValueError(f"SearchRequest: nprobe must be >= 1, "
                                 f"got {self.nprobe}")


@partial(jax.tree_util.register_dataclass,
         data_fields=["votes", "dist", "indices", "labels"],
         meta_fields=["iterations"])
@dataclasses.dataclass(frozen=True)
class SearchResult:
    """One result type for every mode/backend/sharding.

    votes:      (B, K) MCAM vote scores (-inf on masked/empty candidates);
                for mode='full', K == store rows; for 'ideal', votes==-dist
                on valid candidates (and -inf on masked ones).
    dist:       (B, K) ideal digital AVSS distance. Masked rows (slots never
                written, or ragged-shard pad rows) additionally carry the
                integer-exact SHORTLIST_MASK_PENALTY (2**22, added in
                phase 1 -- in every mode, 'ideal' included), which is why
                they sort after every valid candidate while backend and
                sharding bit-parity survives masking.
    indices:    (B, K) global store rows of each candidate.
    labels:     (B, K) candidate labels (-1 on masked/empty candidates).
    iterations: word-line cycles per query (python int; static metadata).

    A tie-heavy toy result -- votes tie at 3.0, so the smaller ideal
    distance wins, and `best()` / `predict()` pick label 9:

    >>> import jax.numpy as jnp
    >>> r = SearchResult(votes=jnp.array([[1.0, 3.0, 3.0]]),
    ...                  dist=jnp.array([[0.0, 2.0, 1.0]]),
    ...                  indices=jnp.array([[0, 1, 2]]),
    ...                  labels=jnp.array([[5, 7, 9]]))
    >>> int(r.best()[0])
    2
    >>> int(r.predict()[0])
    9
    """

    votes: jax.Array
    dist: jax.Array
    indices: jax.Array
    labels: jax.Array
    iterations: int = 0

    def best(self) -> jax.Array:
        """(B,) position of the best candidate per query: max votes, vote
        ties broken exactly by ideal digital distance, then by index
        (stable argmin) -- the paper's retrieval rule."""
        top = self.votes.max(axis=-1, keepdims=True)
        return jnp.argmin(jnp.where(self.votes == top, self.dist, jnp.inf),
                          axis=-1)

    def predict(self) -> jax.Array:
        """(B,) 1-NN label prediction: the label of `best()` per query.

        The -1 sentinel: a label of -1 marks a candidate from a slot that
        was never written (empty store slots, ragged-shard pad rows). Such
        candidates carry -inf votes and the SHORTLIST_MASK_PENALTY on
        their distance, so they can only win when the store holds NO valid
        candidate at all -- in that case every query predicts -1, never an
        arbitrary class label (asserted for every mode/backend/sharding in
        tests/test_store.py). Callers should treat -1 as "no prediction".

        >>> import jax.numpy as jnp
        >>> empty = SearchResult(votes=jnp.full((1, 2), -jnp.inf),
        ...                      dist=jnp.full((1, 2), 2.0 ** 22),
        ...                      indices=jnp.array([[0, 1]]),
        ...                      labels=jnp.array([[-1, -1]]))
        >>> int(empty.predict()[0])        # no valid candidate -> sentinel
        -1
        """
        return jnp.take_along_axis(self.labels, self.best()[:, None], 1)[:, 0]

    def asdict(self) -> dict[str, jax.Array | int]:
        """Legacy result-dict view (the pre-redesign contract)."""
        return {"votes": self.votes, "dist": self.dist,
                "indices": self.indices, "labels": self.labels,
                "iterations": self.iterations}
