"""The typed request/result contract of the unified retrieval API.

Every search in the framework goes through one entry point,

    RetrievalEngine.search(store, queries, SearchRequest) -> SearchResult

replacing the five overlapping ad-hoc paths of the pre-redesign API
(`engine.full` / `engine.two_phase` / `engine.sharded_two_phase`,
`memory.search` / `memory.distributed_search`) and their untyped result
dicts. The request names WHAT to search (mode, k, backend, shard axes);
the store (repro/engine/store.py) carries the programmed memory and its
sharding; the result is a registered pytree safe to return from jit.

Old -> new mapping (the old entry points remain as thin shims):

  engine.full(q, s)                      search(store, q, mode="full")
  engine.two_phase(q, s, k)              search(store, q, mode="two_phase", k)
  engine.sharded_two_phase(q, s, mesh)   search(store.shard(mesh, axes), q,
                                                mode="two_phase", k)
  memory.search(state, q, cfg, ...)      search(store, q, ...)
  memory.distributed_search(state, ...)  search(store.shard(mesh, axes), ...)
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

MODES = ("full", "two_phase", "ideal")


@dataclasses.dataclass(frozen=True)
class SearchRequest:
    """What to search. Hashable -> usable as a jit-static argument.

    mode:    'full'       exact noisy MCAM search of every store row;
             'two_phase'  MXU shortlist by ideal digital distance + exact
                          noisy rescore of the top-k candidates (the
                          production serving path);
             'ideal'      ideal-digital-distance top-k only, no rescore
                          (the cheapest serving path).
    k:       candidate count for 'two_phase' / 'ideal' (ignored by 'full').
    backend: 'auto' defers to the engine's backend; any other value
             ('ref' | 'pallas' | 'mxu' | 'fused') overrides it per request.
    axes:    shard axes override; None defers to the store's own sharding
             (`MemoryStore.shard` records mesh + axes on the store).
    """

    mode: str = "two_phase"
    k: int = 64
    backend: str = "auto"
    axes: tuple | None = None

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"unknown search mode {self.mode!r}; expected one of {MODES}")


@partial(jax.tree_util.register_dataclass,
         data_fields=["votes", "dist", "indices", "labels"],
         meta_fields=["iterations"])
@dataclasses.dataclass(frozen=True)
class SearchResult:
    """One result type for every mode/backend/sharding.

    votes:      (B, K) MCAM vote scores (-inf on masked/empty candidates);
                for mode='full', K == store rows; for 'ideal', votes==-dist
                on valid candidates (and -inf on masked ones).
    dist:       (B, K) ideal digital AVSS distance (masked rows additionally
                carry the integer-exact SHORTLIST_MASK_PENALTY -- in every
                mode, 'ideal' included).
    indices:    (B, K) global store rows of each candidate.
    labels:     (B, K) candidate labels (-1 on masked/empty candidates).
    iterations: word-line cycles per query (python int; static metadata).

    Sentinel: searching a store with NO valid candidates (empty, or entirely
    ragged-pad rows) yields `predict() == -1` for every query -- every
    candidate label is the never-written marker -1, so no arbitrary class
    can win (asserted for every mode/backend/sharding in
    tests/test_store.py).
    """

    votes: jax.Array
    dist: jax.Array
    indices: jax.Array
    labels: jax.Array
    iterations: int = 0

    def best(self) -> jax.Array:
        """(B,) position of the best candidate per query: max votes, vote
        ties broken exactly by ideal digital distance, then by index
        (stable argmin) -- the paper's retrieval rule."""
        top = self.votes.max(axis=-1, keepdims=True)
        return jnp.argmin(jnp.where(self.votes == top, self.dist, jnp.inf),
                          axis=-1)

    def predict(self) -> jax.Array:
        """(B,) 1-NN label prediction (label of `best()` per query);
        -1 when the store held no valid candidate (see class docstring)."""
        return jnp.take_along_axis(self.labels, self.best()[:, None], 1)[:, 0]

    def asdict(self) -> dict:
        """Legacy result-dict view (the pre-redesign contract)."""
        return {"votes": self.votes, "dist": self.dist,
                "indices": self.indices, "labels": self.labels,
                "iterations": self.iterations}
