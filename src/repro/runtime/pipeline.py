"""GPipe-style pipeline parallelism via shard_map + collective_permute.

The production meshes in this repo are (pod, data, model); pipelining is an
OPTIONAL axis for deployments that prefer PP over deeper FSDP (e.g. cross-pod
stages where ICI/DCN bandwidth is the binding constraint). The implementation
is deliberately self-contained: stages are laid out on a 1-D "pipe" mesh
axis, microbatches stream through with the classic GPipe schedule
(P + M - 1 ticks for M microbatches over P stages), and inter-stage hops are
jax.lax.ppermute sends of the activation block.

Each device holds its stage's parameters only => params sharded on the pipe
axis; within a stage, any inner sharding (tensor/fsdp over other mesh axes)
still applies because shard_map composes.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn, params_stacked, x_microbatches, mesh: Mesh,
                   axis: str = "pipe"):
    """Run M microbatches through S pipeline stages.

    stage_fn(stage_params, x) -> x  (the per-stage computation)
    params_stacked: pytree with leading axis S (stage-major).
    x_microbatches: (M, mb, ...) microbatched input.
    Returns (M, mb, ...) outputs (as produced by the LAST stage).
    """
    n_stages = mesh.shape[axis]
    M = x_microbatches.shape[0]
    assert M >= 1

    def per_device(params_local, xs):
        # params_local: this stage's params (leading axis 1) ; xs: (M, mb, ...)
        p = jax.tree_util.tree_map(lambda a: a[0], params_local)
        idx = jax.lax.axis_index(axis)
        n_ticks = n_stages + M - 1
        buf = jnp.zeros_like(xs[0])                  # current activation
        outs = jnp.zeros_like(xs)                    # last stage accumulates

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            mb_in = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, M - 1), keepdims=False)
            buf = jnp.where(idx == 0,
                            jnp.where(t < M, mb_in, jnp.zeros_like(buf)),
                            buf)
            # every stage computes on its current buffer
            y = stage_fn(p, buf)
            # last stage emits microbatch t - (S - 1)
            out_slot = t - (n_stages - 1)
            emit = (idx == n_stages - 1) & (out_slot >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(out_slot, 0, M - 1), 0),
                lambda o: o, outs)
            # shift activations downstream: stage i -> i+1
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # only the last stage holds real outputs; broadcast via masked psum
        if n_stages > 1:
            outs = jax.lax.psum(
                jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs)),
                axis)
        return outs

    from jax.experimental.shard_map import shard_map
    spec_p = jax.tree_util.tree_map(lambda _: P(axis), params_stacked)
    return shard_map(
        per_device, mesh=mesh,
        in_specs=(spec_p, P()), out_specs=P(),
        check_rep=False,
    )(params_stacked, x_microbatches)
