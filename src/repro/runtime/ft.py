"""Fault-tolerance runtime for long multi-pod runs.

What is implementable and TESTED on this single-host container:
  * preemption handling -- SIGTERM/SIGINT triggers a final checkpoint before
    exit (cloud TPU preemption grace window);
  * anomaly step-skipping -- non-finite loss or a gradient-norm spike
    (> spike_factor x running median) skips the update (the batch is
    consumed, so the bad batch is not replayed on restart);
  * step watchdog -- per-step wall-time EWMA + slow-step counter. On a real
    pod, per-host step time is uniform (SPMD lockstep), so the watchdog's
    role is detecting GLOBAL slowdown (stuck host / degraded ICI); its
    signal feeds the restart-and-exclude flow below;
  * elastic restart -- checkpoints are mesh-shape-agnostic (see
    repro.checkpoint), so a failed host set can be excluded and the run
    restored on fewer (or more) devices without conversion.

What is orchestration-level on real clusters (documented, hooks provided):
  rescheduling onto spare capacity, coordinated restart on host failure
  (jax.distributed heartbeats), straggler hardware exclusion. The
  `should_restart` signal below is what that layer consumes.
"""

from __future__ import annotations

import dataclasses
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class AnomalyConfig:
    spike_factor: float = 10.0   # grad-norm spike threshold vs running median
    warmup_steps: int = 20       # collect stats before enforcing
    max_skips_in_row: int = 5    # give up (restart from ckpt) after this


class AnomalyDetector:
    """Decides, per step, whether to apply the update or skip it."""

    def __init__(self, cfg: AnomalyConfig = AnomalyConfig()):
        self.cfg = cfg
        self.norms: list[float] = []
        self.skips_in_row = 0

    def check(self, loss: float, grad_norm: float) -> bool:
        """True => apply update; False => skip step."""
        ok = bool(np.isfinite(loss)) and bool(np.isfinite(grad_norm))
        if ok and len(self.norms) >= self.cfg.warmup_steps:
            med = float(np.median(self.norms[-100:]))
            ok = grad_norm <= self.cfg.spike_factor * max(med, 1e-12)
        if ok:
            self.norms.append(float(grad_norm))
            self.skips_in_row = 0
        else:
            self.skips_in_row += 1
        return ok

    @property
    def should_restart(self) -> bool:
        return self.skips_in_row >= self.cfg.max_skips_in_row


def skip_or_apply(ok: jax.Array, new_tree, old_tree):
    """jnp.where over a pytree: apply the update only when ok (traceable, so
    the skip decision can also live INSIDE a jitted train step)."""
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(ok, n, o), new_tree, old_tree)


class PreemptionHandler:
    """SIGTERM/SIGINT => request graceful stop; train loop checkpoints."""

    def __init__(self):
        self._requested = False
        self._prev = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._prev[sig] = signal.signal(sig, self._handle)
            except ValueError:  # not main thread (tests)
                pass

    def _handle(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested


class StepWatchdog:
    """EWMA step timing; flags sustained slowdown (straggler signal)."""

    def __init__(self, slow_factor: float = 2.0, patience: int = 5):
        self.ewma = None
        self.slow_factor = slow_factor
        self.patience = patience
        self.slow_count = 0
        self._t0 = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> float:
        dt = time.monotonic() - self._t0
        if self.ewma is None:
            self.ewma = dt
        if dt > self.slow_factor * self.ewma:
            self.slow_count += 1
        else:
            self.slow_count = 0
            self.ewma = 0.9 * self.ewma + 0.1 * dt
        return dt

    @property
    def straggling(self) -> bool:
        return self.slow_count >= self.patience
