"""Gradient compression for cross-pod all-reduce (int8 + error feedback).

On the multi-pod mesh the data-parallel gradient reduction crosses the pod
boundary (DCN/ICI-limited). Quantizing gradients to int8 with per-tensor
absmax scales cuts that traffic 4x vs fp32 (2x vs bf16); the residual is fed
back into the next step's gradient (error feedback) so the compression error
stays bounded instead of accumulating.

Usage inside a jitted train step:
    g_q, scale = compress(grads)
    g_q = psum-like reduction of g_q ...      (cheap int math)
    grads = decompress(g_q, scale, n_shards)
Here we expose the codec + an error-feedback wrapper; the train step applies
it around its pod-axis reduction when cfg.grad_compression == 'int8'.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(tree):
    def q(x):
        xf = x.astype(jnp.float32)
        scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-20
        return jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8), scale
    flat = jax.tree_util.tree_map(q, tree)
    istup = lambda t: isinstance(t, tuple)
    qs = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=istup)
    scales = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=istup)
    return qs, scales


def decompress(qs, scales, dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(dtype) * s.astype(dtype), qs, scales)


def with_error_feedback(grads, residual):
    """Add carried residual, compress, and return (decompressed grads as the
    values actually applied, new residual). Simulates the codec locally; the
    distributed reduction happens on the int8 payload."""
    if residual is None:
        residual = jax.tree_util.tree_map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)
    corrected = jax.tree_util.tree_map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    qs, scales = compress(corrected)
    deq = decompress(qs, scales)
    new_residual = jax.tree_util.tree_map(
        lambda c, d: c - d, corrected, deq)
    deq = jax.tree_util.tree_map(lambda d, g: d.astype(g.dtype), deq, grads)
    return deq, new_residual
