"""Pure-jnp oracle for the MCAM search kernels.

Semantics contract (shared bit-exactly with the Pallas kernels):

  inputs:
    q_strings : (B, S, sl) int8   query words per string (AVSS queries are
                                  pre-broadcast over the L word strings)
    s_strings : (N, S, sl) int8   stored words; S = n_seg * L strings/support
    weights   : (S,) f32          per-string accumulation weight (Eq. 2)
    thresholds: (K,) f32          SA reference currents (ascending)

  per (b, n, s):
    m        = |q - s| per cell                               (f32)
    string_id= n * S + s
    dev      = hash_normal(b, string_id, cell; seed)
    m_eff    = clip(m + sigma_device * dev, 0, 3)
    R        = sum_cell rho ** m_eff
    I        = sl / R * (1 + sigma_read * hash_normal(b, string_id; seed+RD))
    votes   += weights[s] * sum_k (I > thresholds[k])
    dist    += weights[s] * sum_cell m

  outputs: votes (B, N) f32, dist (B, N) f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mcam as mcam_lib
from repro.core.encodings import MAX_MISMATCH
from repro.core.mcam import MCAMConfig

READ_SEED_OFFSET = 0x2C1B


def mcam_search_ref(q_strings: jax.Array, s_strings: jax.Array,
                    weights: jax.Array, thresholds: jax.Array,
                    cfg: MCAMConfig, *, noisy: bool = True,
                    query_chunk: int = 8) -> tuple[jax.Array, jax.Array]:
    B, S, sl = q_strings.shape
    N = s_strings.shape[0]

    string_id = (jnp.arange(N, dtype=jnp.uint32)[:, None] * jnp.uint32(S)
                 + jnp.arange(S, dtype=jnp.uint32)[None, :])        # (N, S)
    cell = jnp.arange(sl, dtype=jnp.uint32)

    def one_query(args):
        qs, b = args                                                # (S, sl)
        m = jnp.abs(qs[None].astype(jnp.int32)
                    - s_strings.astype(jnp.int32)).astype(jnp.float32)
        if noisy:
            dev = mcam_lib.hash_normal(
                b, string_id[..., None], cell[None, None, :], seed=cfg.seed)
            m_eff = jnp.clip(m + cfg.sigma_device * dev, 0.0, float(MAX_MISMATCH))
        else:
            m_eff = m
        r = jnp.exp(m_eff * jnp.float32(jnp.log(cfg.rho))).sum(-1)  # (N, S)
        cur = jnp.float32(sl) / r
        if noisy:
            rd = mcam_lib.hash_normal(b, string_id,
                                      seed=cfg.seed + READ_SEED_OFFSET)
            cur = cur * (1.0 + cfg.sigma_read * rd)
        v = (cur[..., None] > thresholds).sum(-1).astype(jnp.float32)
        votes = (v * weights[None, :]).sum(-1)                      # (N,)
        dist = (m.sum(-1) * weights[None, :]).sum(-1)
        return votes, dist

    bidx = jnp.arange(B, dtype=jnp.uint32)
    votes, dist = jax.lax.map(one_query, (q_strings, bidx),
                              batch_size=min(query_chunk, B))
    return votes, dist


def avss_dist_ref(q_values: jax.Array, s_values: jax.Array,
                  sum_lut: jax.Array) -> jax.Array:
    """Ideal (noise-free) AVSS digital distance via the (4, levels) LUT:
    dist[b, n] = sum_d LUT[q[b, d], v[n, d]]. Oracle for the MXU kernel."""
    # (B, d, levels) rows of the LUT selected by the query word
    q_rows = sum_lut[q_values]                     # (B, d, levels)
    v_onehot = jax.nn.one_hot(s_values, sum_lut.shape[1], dtype=sum_lut.dtype)
    return jnp.einsum("bdl,ndl->bn", q_rows, v_onehot)
