"""Jitted wrappers around the Pallas MCAM kernels (padding, layout, dispatch).

Public entry points:

  mcam_search(q_grid, s_grid, weights, cfg, thresholds)
      Exact paper-faithful search; dispatches to the fused Pallas kernel
      (VPU path) with tile padding. Semantics == kernels/ref.py.

  avss_ideal_dist(q_values, s_values, enc)
      Ideal digital AVSS distance via the MXU LUT-matmul kernel.

  two_phase_search(q_values, s_values, cfg, k)
      Beyond-paper TPU pipeline: MXU shortlist (ideal distance) + exact noisy
      rescoring of the top-k candidates. Bit-identical votes to the full
      search for every support that makes the shortlist.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encodings as enc_lib
from repro.core import mcam as mcam_lib
from repro.core.encodings import Encoding
from repro.kernels import mcam_dist, ref
from repro.kernels import mcam_search as mcam_search_kernel


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flatten_strings(grid: jax.Array) -> jax.Array:
    """(X, seg, L, sl) -> (X, seg*L, sl)."""
    x, seg, L, sl = grid.shape
    return grid.reshape(x, seg * L, sl)


def broadcast_query(q_grid: jax.Array, L: int) -> jax.Array:
    """(B, seg, Lq, sl) -> (B, seg, L, sl); AVSS queries have Lq == 1."""
    if q_grid.shape[2] == L:
        return q_grid
    assert q_grid.shape[2] == 1
    return jnp.broadcast_to(q_grid, (*q_grid.shape[:2], L, q_grid.shape[3]))


def mcam_search(q_grid: jax.Array, s_grid: jax.Array, weights: jax.Array,
                cfg, thresholds: jax.Array,
                qidx: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Drop-in kernel backend for repro.core.avss.search_quantized.

    qidx: optional (B,) per-query noise coordinates (default arange(B)).
    `engine.search_tenants` passes each query's rank within its tenant
    group, so batched-across-tenants noise is bit-identical to solo calls.
    """
    L = s_grid.shape[2]
    seg = s_grid.shape[1]
    q = flatten_strings(broadcast_query(q_grid, L)).astype(jnp.int8)
    s = flatten_strings(s_grid).astype(jnp.int8)
    w_flat = jnp.tile(weights.astype(jnp.float32), seg)
    B, N = q.shape[0], s.shape[0]
    if qidx is None:
        qidx = jnp.arange(B, dtype=jnp.uint32)
    tb = min(mcam_search_tile_b(), max(B, 1))
    tn = min(mcam_search_tile_n(), max(N, 1))
    qp = _pad_to(q, 0, tb)
    sp = _pad_to(s, 0, tn)
    votes, dist = mcam_search_kernel.mcam_search_pallas(
        qp, sp, w_flat, thresholds.astype(jnp.float32), cfg.mcam,
        noisy=cfg.noisy, qidx=_pad_to(qidx.astype(jnp.uint32), 0, tb),
        tile_b=tb, tile_n=tn)
    return votes[:B, :N], dist[:B, :N]


def mcam_search_tile_b() -> int:
    return mcam_search_kernel.DEFAULT_TILE_B


def mcam_search_tile_n() -> int:
    return mcam_search_kernel.DEFAULT_TILE_N


# ---------------------------------------------------------------------------
# MXU LUT path.
# ---------------------------------------------------------------------------


def support_projection(s_values: jax.Array, enc: Encoding,
                       dtype=jnp.bfloat16) -> jax.Array:
    """(N, d) int values -> (N, 4*d) LUT projection (precompute at write time).

    bf16 is exact for integer LUT entries < 256 (always true for MTMC with
    CL <= 85); pass dtype=jnp.float32 for long weighted encodings.
    """
    lut = jnp.asarray(enc_lib.avss_sum_lut(enc))          # (4, levels)
    proj = lut.T[s_values]                                # (N, d, 4)
    return proj.reshape(s_values.shape[0], -1).astype(dtype)


def projection_pack_bits(enc: Encoding, dtype=jnp.bfloat16) -> int:
    """Field width (4/8/16/32 bits) of the packed LUT projection for `enc`.

    The smallest width that holds every LUT entry AS STORED in a `dtype`
    projection (bf16 rounds entries >= 256, e.g. long weighted encodings,
    possibly up to the next power of two -- the packed words must reproduce
    the stored values bit-for-bit, not the ideal ones). 32 disables the
    shrink (1 word per int32) but keeps one code path.

    Pure host-side numpy (the LUT is a compile-time constant of the
    encoding), so it stays callable from inside jit traces."""
    lut = np.asarray(enc_lib.avss_sum_lut(enc), np.float32)
    m = float(lut.astype(np.dtype(dtype)).astype(np.float32).max())
    for bits in (4, 8, 16):
        if m < (1 << bits):
            return bits
    return 32


def pack_projection(proj: jax.Array, enc: Encoding) -> jax.Array:
    """(N, C) integer-valued LUT projection -> (N, ceil(C/wpi)) int32.

    wpi = 32 / projection_pack_bits(enc, proj.dtype) projection columns per
    int32 word: column m of the packed word holds projection columns
    {w*dp + m, w in [0, wpi)} with dp = ceil(C/wpi), i.e. the column axis is
    split into wpi CONTIGUOUS chunks so the kernel unpacks with shift/mask
    and dots each chunk against the matching contiguous query slice -- no
    in-kernel reshapes or query reordering. Materialised once at
    MemoryStore.write time (the searches jit against it as a constant);
    shrinks the fused-shortlist streamed operand up to 8x."""
    bits = projection_pack_bits(enc, proj.dtype)
    wpi = 32 // bits
    p = proj.astype(jnp.int32)
    n, c = p.shape
    dp = -(-c // wpi)
    if c != dp * wpi:
        p = jnp.pad(p, ((0, 0), (0, dp * wpi - c)))
    parts = p.reshape(n, wpi, dp)
    shifts = (jnp.arange(wpi, dtype=jnp.int32) * bits)[None, :, None]
    # fields occupy disjoint bit ranges, so the (modular) sum IS the
    # bitwise-or of the shifted fields
    return jnp.sum(parts << shifts, axis=1).astype(jnp.int32)


def query_onehot(q_values: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    """(B, d) ints in [0,4) -> (B, 4*d) one-hot."""
    oh = jax.nn.one_hot(q_values, enc_lib.CELL_STATES, dtype=dtype)
    return oh.reshape(q_values.shape[0], -1)


def avss_ideal_dist(q_values: jax.Array, s_values: jax.Array, enc: Encoding,
                    dtype=jnp.bfloat16, proj: jax.Array | None = None
                    ) -> jax.Array:
    """(B, N) exact digital AVSS distances on the MXU.

    proj: optional precomputed write-time projection (MemoryStore.proj);
    it IS support_projection(s_values, enc) -- a deterministic function of
    the values -- so passing it changes nothing but when it is computed.
    """
    q1h = query_onehot(q_values, dtype)
    sp = support_projection(s_values, enc, dtype) if proj is None \
        else proj.astype(dtype)
    B, K = q1h.shape
    N = sp.shape[0]
    tm, tn, tk = 8, 512, 512
    q1h = _pad_to(_pad_to(q1h, 0, tm), 1, tk)
    sp = _pad_to(_pad_to(sp, 0, tn), 1, tk)
    out = mcam_dist.lut_dist_matmul(q1h, sp, tile_m=tm, tile_n=tn, tile_k=tk)
    return out[:B, :N]


# ---------------------------------------------------------------------------
# Two-phase search: MXU shortlist + exact rescore.
# ---------------------------------------------------------------------------


def rescore_shortlist(q_grid: jax.Array, s_grid: jax.Array,
                      short_idx: jax.Array, weights: jax.Array,
                      cfg, thresholds: jax.Array, *,
                      noise_idx: jax.Array | None = None,
                      noise_qidx: jax.Array | None = None) -> jax.Array:
    """Exact noisy votes for per-query shortlists.

    q_grid (B, seg, Lq, sl); s_grid (N, seg, L, sl); short_idx (B, K).
    Uses GLOBAL support indices for the noise counters, so votes are
    bit-identical to the full search. When `s_grid` holds only a SHARD of
    the store, pass `noise_idx` (B, K) with the global row of each
    candidate while `short_idx` stays shard-local -- this is what makes the
    sharded two-phase search bit-identical to the single-device one.
    `noise_qidx` (B,) is the query-side twin: the noise coordinate of each
    query (default arange(B), the batch position). `engine.search_tenants`
    passes each query's rank within its tenant group, so a batch mixing
    tenants rescores bit-identically to per-tenant solo calls.
    Returns votes (B, K).
    """
    L = s_grid.shape[2]
    q = flatten_strings(broadcast_query(q_grid, L))        # (B, S, sl)
    s = flatten_strings(s_grid)                            # (N, S, sl)
    B, S, sl = q.shape
    sg = s[short_idx]                                      # (B, K, S, sl)
    m = jnp.abs(q[:, None].astype(jnp.int32) - sg.astype(jnp.int32))
    m = m.astype(jnp.float32)                              # (B, K, S, sl)
    if noise_idx is None:
        noise_idx = short_idx
    if noise_qidx is None:
        noise_qidx = jnp.arange(B, dtype=jnp.uint32)
    string_id = (noise_idx.astype(jnp.uint32)[..., None] * jnp.uint32(S)
                 + jnp.arange(S, dtype=jnp.uint32)[None, None, :])
    b_idx = noise_qidx.astype(jnp.uint32)[:, None, None]
    mc = cfg.mcam
    if cfg.noisy:
        cell = jnp.arange(sl, dtype=jnp.uint32)
        dev = mcam_lib.hash_normal(b_idx[..., None], string_id[..., None],
                                   cell, seed=mc.seed)
        m_eff = jnp.clip(m + mc.sigma_device * dev, 0.0,
                         float(enc_lib.MAX_MISMATCH))
    else:
        m_eff = m
    r = jnp.exp(m_eff * jnp.float32(np.log(mc.rho))).sum(-1)
    cur = jnp.float32(sl) / r
    if cfg.noisy:
        rd = mcam_lib.hash_normal(b_idx, string_id,
                                  seed=mc.seed + ref.READ_SEED_OFFSET)
        cur = cur * (1.0 + mc.sigma_read * rd)
    v = (cur[..., None] > thresholds).sum(-1).astype(jnp.float32)
    seg = s_grid.shape[1]
    w_flat = jnp.tile(weights.astype(jnp.float32), seg)
    return (v * w_flat[None, None, :]).sum(-1)             # (B, K)


def two_phase_search(q_values: jax.Array, s_values: jax.Array, cfg,
                     k: int = 64) -> dict[str, jax.Array]:
    """Full beyond-paper pipeline. cfg: repro.core.avss.SearchConfig (avss).

    Backwards-compatible wrapper over the unified API: raw quantized arrays
    are programmed into an anonymous MemoryStore and searched through
    RetrievalEngine.search (MXU shortlist backend) -- results bit-identical
    to the historical RetrievalEngine.two_phase(q, s, k) call.
    """
    from repro.engine import MemoryStore, RetrievalEngine, SearchRequest
    store = MemoryStore.from_quantized(
        s_values, jnp.zeros((s_values.shape[0],), jnp.int32), cfg)
    res = RetrievalEngine(cfg, backend="mxu").search(
        store, q_values, SearchRequest(mode="two_phase", k=k))
    return {"votes": res.votes, "dist": res.dist, "indices": res.indices,
            "iterations": res.iterations}


# The integer-exact penalty added to the phase-1 distance of masked-out
# support rows lives with the kernel that applies it natively; re-exported
# here (its historical home) for the engine and the test suite.
from repro.kernels.shortlist import SHORTLIST_MASK_PENALTY  # noqa: E402


def lut_shortlist(q_values: jax.Array, s_values: jax.Array, enc: Encoding,
                  k: int, dtype=jnp.bfloat16, valid: jax.Array | None = None,
                  proj: jax.Array | None = None,
                  packed: jax.Array | None = None,
                  pack_bits: int | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Fused shortlist: (B, k) distances + indices without materialising the
    (B, N) distance matrix in HBM (kernels/shortlist.py).

    valid: optional (N,) bool; the kernel handles invalid rows natively
    (a per-row SHORTLIST_MASK_PENALTY block stream), so they sort after
    every valid row with no caller-side mask plumbing.
    proj: optional precomputed write-time projection (MemoryStore.proj),
    bit-identical to recomputing it from s_values here.
    packed: optional bit-packed projection (MemoryStore.proj_packed, from
    `pack_projection`); when given it is streamed INSTEAD of the wide
    projection -- up to 8x less kernel HBM traffic, bit-identically.
    pack_bits: the field width `packed` was PACKED with
    (MemoryStore.pack_bits / projection_pack_bits at pack time). Pass it
    whenever the packing dtype can differ from `proj`/`dtype` here: the
    width is a property of the packed operand, and re-deriving it from a
    different dtype mis-unpacks large-LUT encodings (a bf16-rounded LUT
    entry can force 32-bit fields while the f32 projection packs to 16 --
    tests/test_analysis.py pins the b4e edge case).
    """
    from repro.kernels import shortlist as shortlist_kernel
    q1h = query_onehot(q_values, dtype)
    if packed is not None:
        bits = pack_bits if pack_bits is not None else projection_pack_bits(
            enc, proj.dtype if proj is not None else dtype)
        return shortlist_kernel.lut_shortlist_pallas(
            q1h, None, k, valid=valid, packed=packed, pack_bits=bits)
    sp = support_projection(s_values, enc, dtype) if proj is None \
        else proj.astype(dtype)
    return shortlist_kernel.lut_shortlist_pallas(q1h, sp, k, valid=valid)
