"""Pallas TPU kernel: fused MCAM string search (mismatch -> current -> votes).

Computes, for every (query, support) pair in a VMEM-tiled block, the noisy
series-resistance string currents and SA vote accumulation of the simulated
NAND MCAM -- the full inner loop of AVSS/SVSS (see kernels/ref.py for the
exact semantics contract).

Blocking: grid (B/tb, N/tn); each program holds
    q tile (tb, S, sl) int8, s tile (tn, S, sl) int8      in VMEM
and walks the S strings with a fori_loop, producing (tb, tn) vote and
distance accumulators. Per-string intermediates are (tb, tn, sl) f32 --
with tb=8, tn=128, sl=24 that is ~100 KiB, comfortably inside VMEM, and the
int8 tiles give high VMEM reuse: each q/s byte is used tn/tb times.

Noise is the counter-based hash of repro.core.mcam, so results are
bit-identical to the reference regardless of tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import mcam as mcam_lib
from repro.core.encodings import MAX_MISMATCH
from repro.core.mcam import MCAMConfig
from repro.kernels.ref import READ_SEED_OFFSET

DEFAULT_TILE_B = 8
DEFAULT_TILE_N = 128


def _search_kernel(q_ref, s_ref, w_ref, th_ref, qidx_ref, votes_ref,
                   dist_ref, *, cfg: MCAMConfig, noisy: bool, S: int,
                   sl: int, tile_b: int, tile_n: int):
    ni = pl.program_id(1)
    # per-query noise coordinate: an explicit input rather than the tile's
    # batch position, so a caller batching queries from INDEPENDENT stores
    # (engine.search_tenants) can reproduce each query's solo coordinates
    b_abs = qidx_ref[...].astype(jnp.uint32)[:, None]       # (tile_b, 1)
    n_abs = (ni * tile_n
             + jax.lax.broadcasted_iota(jnp.uint32, (1, tile_n), 1))
    cell = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, sl), 2)
    th = th_ref[...]                                     # (K,)
    log_rho = jnp.float32(np.log(cfg.rho))

    def body(s, carry):
        votes, dist = carry
        qw = pl.load(q_ref, (slice(None), pl.ds(s, 1), slice(None)))
        sw = pl.load(s_ref, (slice(None), pl.ds(s, 1), slice(None)))
        w = pl.load(w_ref, (pl.ds(s, 1),))[0]
        # (tb, tn, sl) per-cell mismatch
        m = jnp.abs(qw.astype(jnp.int32)[:, 0][:, None, :]
                    - sw.astype(jnp.int32)[:, 0][None, :, :]).astype(jnp.float32)
        string_id = n_abs.astype(jnp.uint32) * jnp.uint32(S) + s.astype(jnp.uint32)
        if noisy:
            dev = mcam_lib.hash_normal(b_abs[:, :, None], string_id[:, :, None],
                                       cell, seed=cfg.seed)
            m_eff = jnp.clip(m + cfg.sigma_device * dev, 0.0, float(MAX_MISMATCH))
        else:
            m_eff = m
        r = jnp.exp(m_eff * log_rho).sum(-1)             # (tb, tn)
        cur = jnp.float32(sl) / r
        if noisy:
            rd = mcam_lib.hash_normal(b_abs, string_id,
                                      seed=cfg.seed + READ_SEED_OFFSET)
            cur = cur * (1.0 + cfg.sigma_read * rd)
        v = (cur[:, :, None] > th[None, None, :]).sum(-1).astype(jnp.float32)
        return votes + w * v, dist + w * m.sum(-1)

    zeros = jnp.zeros((tile_b, tile_n), jnp.float32)
    votes, dist = jax.lax.fori_loop(0, S, body, (zeros, zeros))
    votes_ref[...] = votes
    dist_ref[...] = dist


def mcam_search_pallas(q_strings: jax.Array, s_strings: jax.Array,
                       weights: jax.Array, thresholds: jax.Array,
                       cfg: MCAMConfig, *, noisy: bool = True,
                       qidx: jax.Array | None = None,
                       tile_b: int = DEFAULT_TILE_B,
                       tile_n: int = DEFAULT_TILE_N,
                       interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """q (B, S, sl) int8, s (N, S, sl) int8 -> votes (B, N), dist (B, N).

    B and N must be multiples of the tile sizes (ops.py pads).
    qidx: optional (B,) uint32 per-query noise coordinates; default
    arange(B) -- the historical batch-position coordinate, bit-identical
    to the pre-parameter kernel.
    """
    B, S, sl = q_strings.shape
    N = s_strings.shape[0]
    assert B % tile_b == 0 and N % tile_n == 0, (B, N, tile_b, tile_n)
    if qidx is None:
        qidx = jnp.arange(B, dtype=jnp.uint32)
    assert qidx.shape == (B,), (qidx.shape, B)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    grid = (B // tile_b, N // tile_n)
    kernel = functools.partial(
        _search_kernel, cfg=cfg, noisy=noisy, S=S, sl=sl,
        tile_b=tile_b, tile_n=tile_n)
    out_shape = [jax.ShapeDtypeStruct((B, N), jnp.float32)] * 2
    votes, dist = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, S, sl), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tile_n, S, sl), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((S,), lambda i, j: (0,)),
            pl.BlockSpec(thresholds.shape, lambda i, j: (0,)),
            pl.BlockSpec((tile_b,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, tile_n), lambda i, j: (i, j)),
            pl.BlockSpec((tile_b, tile_n), lambda i, j: (i, j)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(q_strings, s_strings, weights, thresholds,
      qidx.astype(jnp.uint32))
    return votes, dist
