"""Pallas TPU kernel: fused AVSS shortlist (LUT distance matmul + top-k).

This kernel is the ONE shortlist implementation of the engine: phase 1 of
the two-phase search and the `ideal` serving mode both stream through it at
large N -- unsharded, and (since the sharded-fused rework) per shard inside
the `shard_map` bodies of repro/engine/sharded.py. The dense alternative
materialises the full (B, N) distance matrix in HBM, then runs lax.top_k
over it. This kernel fuses the two: the grid walks the support rows tile by
tile, each step computes the (tile_b, tile_n) distance block on the MXU and
folds it into a running per-query top-k buffer that lives in the
(revisited) output block -- HBM traffic drops from O(B*N) to
O(B*k + N*4d/wpi), where wpi is the packed-word factor below.

Masked rows (never-written slots, ragged-shard label -1 pads) are handled
natively: `valid` enters the kernel as a per-row penalty vector
(0 on valid rows, the integer-exact SHORTLIST_MASK_PENALTY on masked ones)
with its own block stream, so masked rows rank after every valid row while
preserving their relative order -- no extra LUT column, no caller-side
mask plumbing, and shard-local (ragged, non-tile-aligned) row blocks work
unchanged because the wrapper pads any N up to the tile grid.

Tie-breaking contract (bit-identical to jax.lax.top_k on -dist): candidates
are ranked by (distance, support row) lexicographically ascending.
Correctness of the streaming merge (pre-top-k + merge of sorted runs,
which replaced the O(k * (k + tile_n)) per-step extraction loop):

* k is widened internally to kp (the network path pads to a power of two
  >= the 128 lane width, as bitonic stages need it; the native path keeps
  kp = k), and the (tile_b, kp) output block keeps this invariant: after
  grid step j it
  holds the kp lexicographically-smallest (distance, row) pairs of every
  row streamed so far, sorted ascending ((inf, sentinel) pads before kp
  finite candidates exist).
* pre-top-k reduces the incoming (tile_b, tile_n) distance block to its kp
  best, sorted. kp >= k, so no row that can reach the global top-k is ever
  pruned locally (a global top-k row is in its tile's top-k a fortiori).
* the merge of two sorted length-kp runs keeps the kp smallest of their
  union, sorted -- which is exactly the kp best over "rows seen so far",
  restoring the invariant. After the last tile, columns [:k] are the
  global top-k in (distance, row) order, ties included.

Every (distance, row) pair is unique (rows are distinct), so the order is
total and any correct selection yields the same arrays -- which is what
lets the kernel carry two interchangeable implementations of
pre-top-k + merge, selected by `use_network`:

* native (default under interpret mode, i.e. CPU testing): jax.lax.top_k
  for the pre-top-k and a two-key lax.sort for the merge -- single XLA ops.
* network (default when compiling for TPU, where Mosaic lowers neither
  lax.sort nor lax.top_k): a bitonic sorting network built purely from
  roll / compare / where vector ops -- full bitonic sort of the tile,
  then a reverse + pairwise-lexmin + log2(kp)-stage cleanup merge.

Packed LUT operand: the streamed support projection can arrive bit-packed
(kernels/ops.pack_projection, materialised once at MemoryStore.write time)
with wpi = 32/bits words per int32 word, bits in {4, 8, 16, 32} chosen
from the encoding's largest LUT entry. Column m of the packed word holds
projection columns {w*dp + m}, so the kernel unpacks with shift/mask and
accumulates wpi partial dot products over contiguous query slices -- the
sum equals the unpacked dot exactly (integer-valued f32 partials below
2**24), and the streamed operand shrinks up to 8x.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_B = 8
DEFAULT_TILE_N = 512
LANE = 128         # TPU vector lane width; the internal top-k buffer pads
                   # k up to a power of two >= this (the `k_pad` knob)
_IDX_SENTINEL = 2**30  # pads the buffer before kp finite candidates exist

# Added to the phase-1 distance of masked-out support rows (never-written
# slots, ragged-shard label -1 pad rows). A power of two, so it is exact in
# bf16/f32; > any real LUT distance (3 * d * sum(weights) stays far below
# 2**22 for every paper geometry) and small enough that dist + penalty
# remains integer-exact in f32 (< 2**24). Ordering among masked rows is
# preserved, so backend/sharding bit-parity survives masking. Re-exported
# as repro.kernels.ops.SHORTLIST_MASK_PENALTY (its historical home).
SHORTLIST_MASK_PENALTY = 2.0 ** 22


def _pow2_at_least(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


# ---------------------------------------------------------------------------
# Bitonic network building blocks (the TPU-compilable sort: Mosaic has no
# lax.sort / lax.top_k, so selection is compare-exchange stages of pure
# vector ops). All operate on (tile_b, width) blocks with width a power of
# two; `col` is the broadcasted lane-index iota of the same shape.
# ---------------------------------------------------------------------------


def _lex_lt(ad: jax.Array, ai: jax.Array, bd: jax.Array,
            bi: jax.Array) -> jax.Array:
    """(ad, ai) strictly before (bd, bi) under the (distance, row) order."""
    return (ad < bd) | ((ad == bd) & (ai < bi))


def _exchange(x: jax.Array, col: jax.Array, s: int) -> jax.Array:
    """Value held by each column's stride-s partner (column col XOR s)."""
    fwd = jnp.roll(x, -s, axis=1)
    bwd = jnp.roll(x, s, axis=1)
    return jnp.where((col & s) == 0, fwd, bwd)


def _cmpex(d: jax.Array, i: jax.Array, col: jax.Array, s: int,
           desc: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One compare-exchange stage at stride s: within each partner pair the
    lower column keeps the lex-min (ascending blocks; `desc` flips)."""
    pd = _exchange(d, col, s)
    pi = _exchange(i, col, s)
    upper = (col & s) != 0
    take_min = desc == upper          # truth table: min at the asc-lower /
    use_p = take_min == _lex_lt(pd, pi, d, i)   # desc-upper position
    return jnp.where(use_p, pd, d), jnp.where(use_p, pi, i)


def _bitonic_sort(d: jax.Array, i: jax.Array,
                  col: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Full bitonic sort, ascending in (d, i), over the lane axis."""
    width = d.shape[1]
    size = 2
    while size <= width:
        desc = (col & size) != 0      # block direction of this stage
        s = size // 2
        while s >= 1:
            d, i = _cmpex(d, i, col, s, desc)
            s //= 2
        size *= 2
    return d, i


def _reverse_lanes(x: jax.Array, col: jax.Array) -> jax.Array:
    """Lane reversal via XOR-stride exchanges: flipping every bit of the
    column index (width-1-c == c XOR (width-1)) is the composition of one
    unconditional partner swap per stride, and those commute."""
    s = 1
    while s < x.shape[1]:
        x = _exchange(x, col, s)
        s *= 2
    return x


def _merge_topk(ad: jax.Array, ai: jax.Array, bd: jax.Array, bi: jax.Array,
                col: jax.Array) -> tuple[jax.Array, jax.Array]:
    """kp smallest of two sorted length-kp runs, sorted. [A | reverse(B)]
    is bitonic, so the stride-kp compare-exchange restricted to the lower
    half is the pairwise lex-min of A against reversed B; the result is
    bitonic and dominated by the discarded half, and log2(kp) ascending
    cleanup stages sort it (the tail of a standard bitonic merge)."""
    rd = _reverse_lanes(bd, col)
    ri = _reverse_lanes(bi, col)
    swap = _lex_lt(rd, ri, ad, ai)
    d = jnp.where(swap, rd, ad)
    i = jnp.where(swap, ri, ai)
    asc = (col & 0) != 0              # all-False: ascending cleanup
    s = d.shape[1] // 2
    while s >= 1:
        d, i = _cmpex(d, i, col, s, asc)
        s //= 2
    return d, i


# ---------------------------------------------------------------------------
# The kernel.
# ---------------------------------------------------------------------------


def _dist_block(q: jax.Array, s: jax.Array,
                pack_bits: int | None) -> jax.Array:
    """(tile_b, tile_n) integer-valued f32 distance block on the MXU.

    Unpacked (pack_bits None): one dot against the (tile_n, C) projection
    block. Packed: unpack each of the wpi = 32/pack_bits fields of the
    (tile_n, dp) int32 block and accumulate the partial dot against the
    matching contiguous query slice; the partials are integer-valued f32,
    so the sum is exactly the unpacked dot."""
    dims = (((1,), (1,)), ((), ()))
    if pack_bits is None:
        return jax.lax.dot_general(q, s, dims,
                                   preferred_element_type=jnp.float32)
    wpi = 32 // pack_bits
    dp = s.shape[1]
    if wpi == 1:
        parts = [s.astype(q.dtype)]
    else:
        mask = jnp.int32((1 << pack_bits) - 1)
        parts = [((s >> jnp.int32(pack_bits * w)) & mask).astype(q.dtype)
                 for w in range(wpi)]
    dist: jax.Array | None = None
    for w, part in enumerate(parts):
        d = jax.lax.dot_general(q[:, w * dp:(w + 1) * dp], part, dims,
                                preferred_element_type=jnp.float32)
        dist = d if dist is None else dist + d
    assert dist is not None
    return dist


def _shortlist_kernel(q_ref: Any, s_ref: Any, *refs: Any, kp: int,
                      tile_n: int, n_real: int, masked: bool,
                      use_network: bool, pack_bits: int | None,
                      n_padded: bool, merge: bool) -> None:
    pen_ref, d_ref, i_ref = refs if masked else (None, *refs)
    j = pl.program_id(1)

    if merge:
        @pl.when(j == 0)
        def _init() -> None:
            d_ref[...] = jnp.full_like(d_ref, jnp.inf)
            i_ref[...] = jnp.full_like(i_ref, jnp.int32(_IDX_SENTINEL))

    dist = _dist_block(q_ref[...], s_ref[...], pack_bits)
    if masked:
        dist = dist + pen_ref[...]         # (1, tile_n) row penalty stream
    if use_network or n_padded:
        n_abs = (j * tile_n
                 + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1))
    if n_padded:                           # padded support rows rank last
        dist = jnp.where(n_abs < n_real, dist, jnp.inf)

    if use_network:
        col = jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
        td, ti = _bitonic_sort(dist, n_abs, col)
        td, ti = td[:, :kp], ti[:, :kp]    # tile pre-top-k, sorted
    else:
        # interpret-only native path:  # lint: allow=kernel-sort
        neg, pos = jax.lax.top_k(-dist, kp)      # tile pre-top-k, sorted
        td, ti = -neg, j * tile_n + pos
    if not merge:                          # single N step: the tile top-kp
        d_ref[...] = td                    # IS the global top-kp
        i_ref[...] = ti
        return
    if use_network:
        colk = jax.lax.broadcasted_iota(jnp.int32, td.shape, 1)
        d_new, i_new = _merge_topk(d_ref[...], i_ref[...], td, ti, colk)
    else:
        cd = jnp.concatenate([d_ref[...], td], axis=1)
        ci = jnp.concatenate([i_ref[...], ti], axis=1)
        # interpret-only native path:  # lint: allow=kernel-sort
        sd, si = jax.lax.sort((cd, ci), dimension=1, num_keys=2)
        d_new, i_new = sd[:, :kp], si[:, :kp]
    d_ref[...] = d_new
    i_ref[...] = i_new


def lut_shortlist_pallas(q_onehot: jax.Array, s_proj: jax.Array | None,
                         k: int, *,
                         valid: jax.Array | None = None,
                         tile_b: int = DEFAULT_TILE_B,
                         tile_n: int = DEFAULT_TILE_N,
                         k_pad: int = LANE,
                         packed: jax.Array | None = None,
                         pack_bits: int | None = None,
                         interpret: bool | None = None,
                         use_network: bool | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """(B, 4d) one-hot queries x (N, 4d) LUT projections -> top-k shortlist.

    Returns (dist (B, k) f32, indices (B, k) int32), ranked ascending by
    (distance, support row) -- the exact order jax.lax.top_k(-dist) yields.
    Requires k <= N. N may be any size (ragged shard-local blocks included);
    rows are padded up to the tile grid internally and padded rows can never
    enter the top-k.

    valid: optional (N,) bool row mask. Masked rows get the integer-exact
    SHORTLIST_MASK_PENALTY added to their distance INSIDE the kernel (one
    (1, tile_n) penalty block per grid step), so they rank after every valid
    row, keep their relative (distance, row) order, and surface the penalty
    in their returned dist -- bit-identical to penalising a dense (B, N)
    matrix before lax.top_k.

    tile_b / tile_n / k_pad: tiling knobs (benchmarks/autotune_shortlist.py
    sweeps them). tile_n is rounded to a power of two >= the internal
    buffer width kp (network path: pow2(max(k, k_pad)); native path: k,
    where k_pad is ignored); results are identical for any legal tiling
    (tests/test_engine.py pins this).

    packed / pack_bits: optional bit-packed projection (N, ceil(C/wpi))
    int32 from kernels/ops.pack_projection, streamed INSTEAD of s_proj
    (which may then be None) -- up to 8x less HBM traffic, bit-identical
    distances (see module docstring).

    use_network: force the bitonic-network selection path (the compiled-TPU
    default) or the native lax.top_k/lax.sort path (the interpret default);
    both produce bit-identical results -- the property tests toggle this.

    Example -- supports with constant rows (row r at distance 3*r from the
    all-zeros query) and row 2 masked out:

    >>> import jax, jax.numpy as jnp
    >>> q = jax.nn.one_hot(jnp.zeros((2, 3), jnp.int32), 4).reshape(2, 12)
    >>> s = jnp.tile(jnp.arange(6, dtype=jnp.float32)[:, None], (1, 12))
    >>> valid = jnp.array([True, True, False, True, True, True])
    >>> dist, idx = lut_shortlist_pallas(q, s, 3, valid=valid)
    >>> idx[0].tolist()            # masked row 2 ranks after every valid row
    [0, 1, 3]
    >>> dist[0].tolist()
    [0.0, 3.0, 9.0]
    >>> _, idx_all = lut_shortlist_pallas(q, s, 6, valid=valid)
    >>> idx_all[0].tolist()        # ...but keeps its relative order at the tail
    [0, 1, 3, 4, 5, 2]
    """
    B, K = q_onehot.shape
    if packed is not None:
        assert pack_bits is not None and pack_bits in (4, 8, 16, 32), \
            pack_bits
        N, dp = packed.shape
        wpi = 32 // pack_bits
        width = dp * wpi
        assert width >= K, (width, K)
        # bf16 holds unpacked fields (and the 0/1 one-hot) exactly only up
        # to 8-bit entries; wider fields force the f32 operand path
        if pack_bits > 8 or q_onehot.dtype not in (jnp.bfloat16,
                                                   jnp.float32):
            q_onehot = q_onehot.astype(jnp.float32)
        if width > K:
            q_onehot = jnp.pad(q_onehot, ((0, 0), (0, width - K)))
        s_stream, s_width = packed, dp
    else:
        assert s_proj is not None, "need s_proj when packed is not given"
        N, K2 = s_proj.shape
        assert K == K2, (K, K2)
        pack_bits = None
        if q_onehot.dtype != s_proj.dtype:   # mixed f32 query / bf16 proj is
            dt = jnp.promote_types(q_onehot.dtype, s_proj.dtype)  # exact:
            q_onehot = q_onehot.astype(dt)   # both hold small integers
            s_proj = s_proj.astype(dt)
        s_stream, s_width = s_proj, K
    assert 0 < k <= N, (k, N)

    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if use_network is None:
        # Mosaic lowers neither lax.sort nor lax.top_k; the interpreter
        # (plain XLA) runs them natively and far faster than the network
        use_network = not interpret
    if interpret and tile_b == DEFAULT_TILE_B and tile_n == DEFAULT_TILE_N:
        # untuned interpret-mode run (CPU testing / benching): there is no
        # VMEM budget to respect, while every extra grid step costs a pass
        # through the interpreter's block plumbing -- so default to the
        # widest tiles. Explicit tile arguments (the autotune sweep, the
        # tiling-invariance tests) are honoured as given.
        tile_b, tile_n = max(tile_b, min(B, 64)), max(N, tile_n)
    if use_network:
        # the bitonic network needs power-of-two run widths; pad k up to
        # the lane width so compare-exchange stages stay full-lane
        kp = _pow2_at_least(max(k, k_pad, 1))
    else:
        # the native path has no width constraint -- and any kp > k forces
        # a downstream [:, :k] slice of the pallas output, which XLA:CPU
        # fuses into the interpret grid loop catastrophically (~15x)
        kp = max(k, 1)
    tile_b = min(tile_b, B)
    tile_n = max(_pow2_at_least(min(tile_n, max(N, 1))), kp)
    pad_b = (-B) % tile_b
    pad_n = (-N) % tile_n
    if pad_b:
        q_onehot = jnp.pad(q_onehot, ((0, pad_b), (0, 0)))
    if pad_n:
        s_stream = jnp.pad(s_stream, ((0, pad_n), (0, 0)))
    Bp, Np = B + pad_b, N + pad_n
    grid = (Bp // tile_b, Np // tile_n)  # N axis innermost: sequential merge
    args = [q_onehot, s_stream]
    in_specs = [
        pl.BlockSpec((tile_b, q_onehot.shape[1]), lambda i, j: (i, 0)),
        pl.BlockSpec((tile_n, s_width), lambda i, j: (j, 0)),
    ]
    if valid is not None:
        pen = jnp.where(valid, 0.0,
                        SHORTLIST_MASK_PENALTY).astype(jnp.float32)[None, :]
        if pad_n:
            pen = jnp.pad(pen, ((0, 0), (0, pad_n)))
        args.append(pen)
        in_specs.append(pl.BlockSpec((1, tile_n), lambda i, j: (0, j)))
    kernel = functools.partial(_shortlist_kernel, kp=kp, tile_n=tile_n,
                               n_real=N, masked=valid is not None,
                               use_network=use_network, pack_bits=pack_bits,
                               n_padded=pad_n != 0, merge=grid[1] > 1)
    # the scope tags every op of the fused path in compiled HLO metadata, so
    # tests can assert the kernel actually engaged (or stayed out) on a
    # given route -- see tests/test_engine.py
    with jax.named_scope("shortlist_fused"):
        dist, idx = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((tile_b, kp), lambda i, j: (i, 0)),
                pl.BlockSpec((tile_b, kp), lambda i, j: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((Bp, kp), jnp.float32),
                jax.ShapeDtypeStruct((Bp, kp), jnp.int32),
            ],
            interpret=interpret,
        )(*args)
    return dist[:B, :k], idx[:B, :k]
