"""Pallas TPU kernel: fused AVSS shortlist (LUT distance matmul + top-k).

Phase 1 of the two-phase search -- and, since the ideal-serving rework, the
unsharded `ideal` mode of `RetrievalEngine.search` at large N (>=
engine.IDEAL_FUSED_MIN_ROWS) -- normally materialises the full (B, N)
distance matrix in HBM, then runs lax.top_k over it. This kernel fuses the
two: the grid walks the support rows tile by tile, each step computes the
(tile_b, tile_n) distance block on the MXU and folds it into a running
per-query top-k buffer that lives in the (revisited) output block -- HBM
traffic drops from O(B*N) to O(B*k + N*4d).

Tie-breaking contract (bit-identical to jax.lax.top_k on -dist): candidates
are ranked by (distance, support row) lexicographically ascending.
Correctness of the streaming merge:

* the running buffer is kept sorted in that order, and every buffered row
  index is strictly smaller than any index in the incoming tile (the grid
  walks rows in ascending order), so
* k rounds of first-occurrence argmin extraction over [buffer | tile]
  reproduce the global order exactly, ties included.

The extraction is all vector ops (min / compare / cumsum / where) -- no
gather, scatter or sort -- so it maps onto the VPU; cost is k passes over a
(tile_b, k + tile_n) block per tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_B = 8
DEFAULT_TILE_N = 512
_IDX_SENTINEL = 2**30  # pads the buffer before k finite candidates exist


def _shortlist_kernel(q_ref, s_ref, d_ref, i_ref, *, k: int, tile_n: int,
                      n_real: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        d_ref[...] = jnp.full_like(d_ref, jnp.inf)
        i_ref[...] = jnp.full_like(i_ref, jnp.int32(_IDX_SENTINEL))

    # (tile_b, tile_n) distance block on the MXU; f32 accumulation is exact
    # for the integer-valued LUT entries.
    dist = jax.lax.dot_general(
        q_ref[...], s_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_abs = (j * tile_n
             + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1))
    dist = jnp.where(n_abs < n_real, dist, jnp.inf)  # padded support rows

    cand_d = jnp.concatenate([d_ref[...], dist], axis=1)   # (tb, k + tn)
    cand_i = jnp.concatenate([i_ref[...], n_abs], axis=1)
    col = jax.lax.broadcasted_iota(jnp.int32, d_ref.shape, 1)  # (tb, k)

    def extract(t, carry):
        cand_d, out_d, out_i = carry
        best = jnp.min(cand_d, axis=1, keepdims=True)      # (tb, 1)
        hit = cand_d == best
        first = hit & (jnp.cumsum(hit.astype(jnp.int32), axis=1) == 1)
        best_i = jnp.sum(jnp.where(first, cand_i, 0), axis=1, keepdims=True)
        cand_d = jnp.where(first, jnp.inf, cand_d)
        sel = col == t
        return (cand_d,
                jnp.where(sel, best, out_d),
                jnp.where(sel, best_i, out_i))

    zeros_d = jnp.zeros_like(d_ref)
    zeros_i = jnp.zeros_like(i_ref)
    _, out_d, out_i = jax.lax.fori_loop(
        0, k, extract, (cand_d, zeros_d, zeros_i))
    d_ref[...] = out_d
    i_ref[...] = out_i


def lut_shortlist_pallas(q_onehot: jax.Array, s_proj: jax.Array, k: int, *,
                         tile_b: int = DEFAULT_TILE_B,
                         tile_n: int = DEFAULT_TILE_N,
                         interpret: bool | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """(B, 4d) one-hot queries x (N, 4d) LUT projections -> top-k shortlist.

    Returns (dist (B, k) f32, indices (B, k) int32), ranked ascending by
    (distance, support row) -- the exact order jax.lax.top_k(-dist) yields.
    Requires k <= N.
    """
    B, K = q_onehot.shape
    N, K2 = s_proj.shape
    assert K == K2, (K, K2)
    assert 0 < k <= N, (k, N)
    tile_b = min(tile_b, B)
    tile_n = min(tile_n, max(N, 1))
    pad_b = (-B) % tile_b
    pad_n = (-N) % tile_n
    if pad_b:
        q_onehot = jnp.pad(q_onehot, ((0, pad_b), (0, 0)))
    if pad_n:
        s_proj = jnp.pad(s_proj, ((0, pad_n), (0, 0)))
    Bp, Np = B + pad_b, N + pad_n
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    grid = (Bp // tile_b, Np // tile_n)  # N axis innermost: sequential merge
    kernel = functools.partial(_shortlist_kernel, k=k, tile_n=tile_n,
                               n_real=N)
    dist, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, K), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, K), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_b, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, k), jnp.float32),
            jax.ShapeDtypeStruct((Bp, k), jnp.int32),
        ],
        interpret=interpret,
    )(q_onehot, s_proj)
    return dist[:B], idx[:B]
