"""Pallas TPU kernel: fused AVSS shortlist (LUT distance matmul + top-k).

This kernel is the ONE shortlist implementation of the engine: phase 1 of
the two-phase search and the `ideal` serving mode both stream through it at
large N -- unsharded, and (since the sharded-fused rework) per shard inside
the `shard_map` bodies of repro/engine/sharded.py. The dense alternative
materialises the full (B, N) distance matrix in HBM, then runs lax.top_k
over it. This kernel fuses the two: the grid walks the support rows tile by
tile, each step computes the (tile_b, tile_n) distance block on the MXU and
folds it into a running per-query top-k buffer that lives in the
(revisited) output block -- HBM traffic drops from O(B*N) to
O(B*k + N*4d).

Masked rows (never-written slots, ragged-shard label -1 pads) are handled
natively: `valid` enters the kernel as a per-row penalty vector
(0 on valid rows, the integer-exact SHORTLIST_MASK_PENALTY on masked ones)
with its own block stream, so masked rows rank after every valid row while
preserving their relative order -- no extra LUT column, no caller-side
mask plumbing, and shard-local (ragged, non-tile-aligned) row blocks work
unchanged because the wrapper pads any N up to the tile grid.

Tie-breaking contract (bit-identical to jax.lax.top_k on -dist): candidates
are ranked by (distance, support row) lexicographically ascending.
Correctness of the streaming merge:

* the running buffer is kept sorted in that order, and every buffered row
  index is strictly smaller than any index in the incoming tile (the grid
  walks rows in ascending order), so
* k rounds of first-occurrence argmin extraction over [buffer | tile]
  reproduce the global order exactly, ties included.

The extraction is all vector ops (min / compare / cumsum / where) -- no
gather, scatter or sort -- so it maps onto the VPU; cost is k passes over a
(tile_b, k + tile_n) block per tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_B = 8
DEFAULT_TILE_N = 512
_IDX_SENTINEL = 2**30  # pads the buffer before k finite candidates exist

# Added to the phase-1 distance of masked-out support rows (never-written
# slots, ragged-shard label -1 pad rows). A power of two, so it is exact in
# bf16/f32; > any real LUT distance (3 * d * sum(weights) stays far below
# 2**22 for every paper geometry) and small enough that dist + penalty
# remains integer-exact in f32 (< 2**24). Ordering among masked rows is
# preserved, so backend/sharding bit-parity survives masking. Re-exported
# as repro.kernels.ops.SHORTLIST_MASK_PENALTY (its historical home).
SHORTLIST_MASK_PENALTY = 2.0 ** 22


def _shortlist_kernel(q_ref, s_ref, *refs, k: int, tile_n: int,
                      n_real: int, masked: bool):
    pen_ref, d_ref, i_ref = refs if masked else (None, *refs)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        d_ref[...] = jnp.full_like(d_ref, jnp.inf)
        i_ref[...] = jnp.full_like(i_ref, jnp.int32(_IDX_SENTINEL))

    # (tile_b, tile_n) distance block on the MXU; f32 accumulation is exact
    # for the integer-valued LUT entries.
    dist = jax.lax.dot_general(
        q_ref[...], s_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    if masked:
        dist = dist + pen_ref[...]         # (1, tile_n) row penalty stream
    n_abs = (j * tile_n
             + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1))
    dist = jnp.where(n_abs < n_real, dist, jnp.inf)  # padded support rows

    cand_d = jnp.concatenate([d_ref[...], dist], axis=1)   # (tb, k + tn)
    cand_i = jnp.concatenate([i_ref[...], n_abs], axis=1)
    col = jax.lax.broadcasted_iota(jnp.int32, d_ref.shape, 1)  # (tb, k)

    def extract(t, carry):
        cand_d, out_d, out_i = carry
        best = jnp.min(cand_d, axis=1, keepdims=True)      # (tb, 1)
        hit = cand_d == best
        first = hit & (jnp.cumsum(hit.astype(jnp.int32), axis=1) == 1)
        best_i = jnp.sum(jnp.where(first, cand_i, 0), axis=1, keepdims=True)
        cand_d = jnp.where(first, jnp.inf, cand_d)
        sel = col == t
        return (cand_d,
                jnp.where(sel, best, out_d),
                jnp.where(sel, best_i, out_i))

    zeros_d = jnp.zeros_like(d_ref)
    zeros_i = jnp.zeros_like(i_ref)
    _, out_d, out_i = jax.lax.fori_loop(
        0, k, extract, (cand_d, zeros_d, zeros_i))
    d_ref[...] = out_d
    i_ref[...] = out_i


def lut_shortlist_pallas(q_onehot: jax.Array, s_proj: jax.Array, k: int, *,
                         valid: jax.Array | None = None,
                         tile_b: int = DEFAULT_TILE_B,
                         tile_n: int = DEFAULT_TILE_N,
                         interpret: bool | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """(B, 4d) one-hot queries x (N, 4d) LUT projections -> top-k shortlist.

    Returns (dist (B, k) f32, indices (B, k) int32), ranked ascending by
    (distance, support row) -- the exact order jax.lax.top_k(-dist) yields.
    Requires k <= N. N may be any size (ragged shard-local blocks included);
    rows are padded up to the tile grid internally and padded rows can never
    enter the top-k.

    valid: optional (N,) bool row mask. Masked rows get the integer-exact
    SHORTLIST_MASK_PENALTY added to their distance INSIDE the kernel (one
    (1, tile_n) penalty block per grid step), so they rank after every valid
    row, keep their relative (distance, row) order, and surface the penalty
    in their returned dist -- bit-identical to penalising a dense (B, N)
    matrix before lax.top_k.

    Example -- supports with constant rows (row r at distance 3*r from the
    all-zeros query) and row 2 masked out:

    >>> import jax, jax.numpy as jnp
    >>> q = jax.nn.one_hot(jnp.zeros((2, 3), jnp.int32), 4).reshape(2, 12)
    >>> s = jnp.tile(jnp.arange(6, dtype=jnp.float32)[:, None], (1, 12))
    >>> valid = jnp.array([True, True, False, True, True, True])
    >>> dist, idx = lut_shortlist_pallas(q, s, 3, valid=valid)
    >>> idx[0].tolist()            # masked row 2 ranks after every valid row
    [0, 1, 3]
    >>> dist[0].tolist()
    [0.0, 3.0, 9.0]
    >>> _, idx_all = lut_shortlist_pallas(q, s, 6, valid=valid)
    >>> idx_all[0].tolist()        # ...but keeps its relative order at the tail
    [0, 1, 3, 4, 5, 2]
    """
    B, K = q_onehot.shape
    N, K2 = s_proj.shape
    assert K == K2, (K, K2)
    assert 0 < k <= N, (k, N)
    if q_onehot.dtype != s_proj.dtype:     # mixed f32 query / bf16 proj is
        dt = jnp.promote_types(q_onehot.dtype, s_proj.dtype)  # exact: both
        q_onehot = q_onehot.astype(dt)     # hold small integers
        s_proj = s_proj.astype(dt)
    tile_b = min(tile_b, B)
    tile_n = min(tile_n, max(N, 1))
    pad_b = (-B) % tile_b
    pad_n = (-N) % tile_n
    if pad_b:
        q_onehot = jnp.pad(q_onehot, ((0, pad_b), (0, 0)))
    if pad_n:
        s_proj = jnp.pad(s_proj, ((0, pad_n), (0, 0)))
    Bp, Np = B + pad_b, N + pad_n
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    grid = (Bp // tile_b, Np // tile_n)  # N axis innermost: sequential merge
    args = [q_onehot, s_proj]
    in_specs = [
        pl.BlockSpec((tile_b, K), lambda i, j: (i, 0)),
        pl.BlockSpec((tile_n, K), lambda i, j: (j, 0)),
    ]
    if valid is not None:
        pen = jnp.where(valid, 0.0,
                        SHORTLIST_MASK_PENALTY).astype(jnp.float32)[None, :]
        if pad_n:
            pen = jnp.pad(pen, ((0, 0), (0, pad_n)))
        args.append(pen)
        in_specs.append(pl.BlockSpec((1, tile_n), lambda i, j: (0, j)))
    kernel = functools.partial(_shortlist_kernel, k=k, tile_n=tile_n,
                               n_real=N, masked=valid is not None)
    # the scope tags every op of the fused path in compiled HLO metadata, so
    # tests can assert the kernel actually engaged (or stayed out) on a
    # given route -- see tests/test_engine.py
    with jax.named_scope("shortlist_fused"):
        dist, idx = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((tile_b, k), lambda i, j: (i, 0)),
                pl.BlockSpec((tile_b, k), lambda i, j: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((Bp, k), jnp.float32),
                jax.ShapeDtypeStruct((Bp, k), jnp.int32),
            ],
            interpret=interpret,
        )(*args)
    return dist[:B], idx[:B]
