"""Pallas TPU kernel: AVSS distance as an MXU matmul (beyond-paper, TPU-native).

Because AVSS fixes the query to 4 levels and the support encoding is a pure
function of the quantized support VALUE, the per-dimension summed mismatch is
a (4 x levels) lookup table LUT[q, v] = sum_c w_c |q - code_c(v)|. Projecting
the table onto the support side,

    s_proj[n, 4*d + q] = LUT[q, v[n, d]]          (precomputed once per write)
    q_onehot[b, 4*d + q] = 1[q_values[b, d] == q] (cheap, per query batch)

turns the entire B x N distance computation into ONE bf16 matmul with inner
dimension 4d -- the TPU's native systolic primitive, replacing the paper's
analog per-string current accumulation. The kernel below is a standard
VMEM-blocked matmul accumulating f32 into the output block across the K grid
axis (the output block index is independent of k, so the block stays resident).

Arithmetic intensity: 2*bm*bn*bk flops per (bm*bk + bn*bk)*2 bytes; with
bn = bk = 512 each byte feeds ~hundreds of MACs -- compute-bound on the MXU,
vs the VPU-bound exact-search kernel. Used as phase 1 of the two-phase search
(shortlist by ideal distance, rescore the shortlist with the noisy string
model).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(q_ref, s_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        q_ref[...], s_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def lut_dist_matmul(q_onehot: jax.Array, s_proj: jax.Array, *,
                    tile_m: int = 8, tile_n: int = 512, tile_k: int = 512,
                    interpret: bool | None = None) -> jax.Array:
    """(B, K) x (N, K) -> (B, N) f32 distances; K = 4*d.

    All dims must be divisible by their tiles (ops.py pads: padded support
    rows project to zero and padded query columns are zero one-hots, so
    padding never perturbs real distances).
    """
    B, K = q_onehot.shape
    N = s_proj.shape[0]
    tile_m = min(tile_m, B)
    tile_n = min(tile_n, N)
    tile_k = min(tile_k, K)
    assert B % tile_m == 0 and N % tile_n == 0 and K % tile_k == 0, (B, N, K)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    grid = (B // tile_m, N // tile_n, K // tile_k)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_n, tile_k), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
    )(q_onehot, s_proj)
